//! End-to-end serving driver (the DESIGN.md §6 validation run), and the
//! mid-download serving demo: the coordinator answers inference requests
//! with the stage-k approximate model while later stages are still
//! streaming, and the answers upgrade to full precision once the
//! session's `Finished` event fires.
//!
//! Composes every layer of the system on one real workload:
//!
//!   model server (bandwidth-shaped TCP) ──► ProgressiveSession
//!        │                                        │ publishes each stage into
//!        │                                        ▼ its hot-swappable handle
//!   eval images ──► request load ──► Router::bind(ApproxModel) + Batcher
//!                                           │ (backend executable, weights
//!                                           ▼  refresh on every upgrade)
//!                        per-request replies tagged with the weight bits
//!
//! While the model is still downloading, three client threads keep
//! issuing classification requests; the coordinator serves them against
//! whatever approximation has arrived. The run reports the latency
//! histogram, throughput, how accuracy climbs as stages land — and
//! asserts that some replies were served *below* full precision (the
//! mid-download claim) and that the final replies match a direct
//! full-precision inference (the upgrade claim).
//!
//! With artifacts it streams the trained `cnn` at 1 MB/s; without them a
//! synthetic fixture model at 0.05 MB/s, so the demo runs in CI.
//!
//! Run with: `cargo run --release --example serve_e2e`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use prognet::client::{ProgressiveSession, SessionEvent};
use prognet::coordinator::{BatcherConfig, Router};
use prognet::models::Registry;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};
use prognet::testutil::fixture;
use prognet::util::stats::{fmt_secs, Summary};

const LOAD_THREADS: usize = 3;

fn main() -> prognet::Result<()> {
    let t0 = Instant::now();
    // --- infrastructure (artifacts when built, fixture fallback for CI)
    let with_artifacts = prognet::artifacts_available();
    let (repo, model, speed_mbps, registry) = if with_artifacts {
        (
            Arc::new(Repository::open_default()?),
            "cnn",
            1.0,
            Registry::open_default()?,
        )
    } else {
        println!("artifacts not built — serving a synthetic fixture model instead");
        let reg = fixture::executable_models_big("example-serve-e2e")?;
        let reg2 = Registry::open(&fixture::fixture_root("example-serve-e2e"))?;
        (Arc::new(Repository::new(reg)), "dense2b", 0.05, reg2)
    };
    let server = Server::start("127.0.0.1:0", repo.clone(), ServerConfig::default())?;
    let engine = Engine::global()?;
    let manifest = repo.registry().get(model)?.clone();
    let eval = if with_artifacts {
        prognet::eval::EvalSet::load_named(&manifest.dataset)?
    } else {
        fixture::synthetic_eval(&manifest, 64, 9)
    };
    let eval = Arc::new(eval);
    let router = Arc::new(Router::new(
        engine.clone(),
        registry,
        BatcherConfig::default(),
    ));

    // --- the progressive session: no workload; it only downloads,
    // reconstructs, and publishes each stage into its ApproxModel
    let session = Arc::new(ModelSession::load_batches(&engine, &manifest, &[1, 32])?);
    let live = ProgressiveSession::builder(model)
        .addr(server.addr())
        .speed_mbps(speed_mbps)
        .runtime(model, session.clone())
        .start()?;

    // --- bind the hot-swapping handle into the coordinator: the batcher
    // now serves THIS download, refreshing weights on every upgrade
    let approx = live.approx_model().expect("runtime bound").clone();
    router.bind(model, approx);

    // --- request load: fires as soon as the first stage is published
    let done = Arc::new(AtomicBool::new(false));
    let load_handles: Vec<_> = (0..LOAD_THREADS)
        .map(|worker| {
            let router = router.clone();
            let eval = eval.clone();
            let done = done.clone();
            let classes = manifest.classes;
            let model = model.to_string();
            std::thread::spawn(move || {
                let mut lat = Summary::new();
                let mut correct_by_bits: Vec<(u32, bool)> = Vec::new();
                let mut i = worker;
                while !done.load(Ordering::Relaxed) {
                    if !router.model_ready(&model) {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                        continue;
                    }
                    let img = eval.image(i % eval.n).to_vec();
                    let label = eval.labels[i % eval.n] as usize;
                    match router.infer(&model, img) {
                        Ok(reply) => {
                            lat.add(reply.latency.as_secs_f64());
                            if let Ok(out) = reply.output {
                                let pred = out[..classes]
                                    .iter()
                                    .enumerate()
                                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                    .map(|(j, _)| j)
                                    .unwrap();
                                correct_by_bits.push((reply.cum_bits, pred == label));
                            }
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(2)),
                    }
                    i += LOAD_THREADS;
                }
                (lat, correct_by_bits)
            })
        })
        .collect();

    // --- walk the event stream while the load hammers the router
    println!(
        "downloading '{model}' at {speed_mbps} MB/s while serving requests on {LOAD_THREADS} threads…"
    );
    while let Some(ev) = live.next_event() {
        match ev {
            SessionEvent::ModelReady {
                stage,
                cum_bits,
                version,
                t,
                ..
            } => {
                println!(
                    "  stage {stage} ({cum_bits:>2} bits, v{version}) published at {}",
                    fmt_secs(t)
                );
            }
            SessionEvent::Finished(s) => {
                println!(
                    "  transfer complete: {} bytes in {}",
                    s.bytes,
                    fmt_secs(s.t_transfer_complete)
                );
            }
            _ => {}
        }
    }
    let report = live.finish()?;

    // let the tail of the request load run against the final model
    std::thread::sleep(std::time::Duration::from_millis(200));
    done.store(true, Ordering::Relaxed);

    let mut lat_all = Summary::new();
    let mut by_bits: std::collections::BTreeMap<u32, (usize, usize)> = Default::default();
    for h in load_handles {
        let (lat, correct) = h.join().unwrap();
        for s in lat.samples() {
            lat_all.add(*s);
        }
        for (bits, ok) in correct {
            let e = by_bits.entry(bits).or_insert((0, 0));
            e.0 += ok as usize;
            e.1 += 1;
        }
    }

    println!("\n=== serve_e2e report ===");
    println!(
        "transfer: {} bytes in {} ({} stage upgrades)",
        report.summary.bytes,
        fmt_secs(report.summary.t_transfer_complete),
        report.order.len()
    );
    println!(
        "requests: {} served | throughput {:.1} req/s | latency mean {} p50 {} p99 {}",
        lat_all.n(),
        lat_all.n() as f64 / t0.elapsed().as_secs_f64(),
        fmt_secs(lat_all.mean()),
        fmt_secs(lat_all.median()),
        fmt_secs(lat_all.p99()),
    );
    println!("accuracy of served replies by weight precision:");
    for (bits, (ok, total)) in &by_bits {
        println!(
            "  {bits:>2} bits: {:>5.1}% of {total} requests",
            *ok as f64 / *total as f64 * 100.0
        );
    }

    // --- the mid-download claim: some replies used an approximation
    anyhow::ensure!(lat_all.n() > 0, "no requests served");
    let min_bits = *by_bits.keys().next().unwrap();
    let max_bits = *by_bits.keys().next_back().unwrap();
    anyhow::ensure!(
        min_bits < 16,
        "no mid-download replies observed (min precision {min_bits} bits)"
    );
    anyhow::ensure!(
        max_bits == 16,
        "serving never reached full precision (max {max_bits} bits)"
    );

    // --- the upgrade claim: after Finished, a fresh request answers with
    // the full-precision weights, matching direct inference exactly
    let probe = eval.image(0).to_vec();
    let reply = router.infer(model, probe.clone())?;
    anyhow::ensure!(reply.cum_bits == 16, "post-Finished reply not full precision");
    let final_flat = report
        .assembler(model)
        .expect("session retains the assembler")
        .flat()
        .to_vec();
    let direct = session.infer(&probe, 1, &final_flat)?;
    let routed = reply.output.expect("routed inference failed");
    for (a, b) in routed.iter().zip(direct.row(0)) {
        anyhow::ensure!((a - b).abs() < 1e-4, "routed {a} vs direct {b}");
    }

    if with_artifacts {
        let (_, (ok, total)) = by_bits.iter().next_back().unwrap();
        let final_acc = *ok as f64 / *total as f64;
        anyhow::ensure!(
            final_acc > 0.8,
            "final-precision serving accuracy too low: {final_acc:.2}"
        );
    }
    println!(
        "\nOK — all layers composed: shaped transport → progressive\n\
         reconstruction → hot-swapped ApproxModel → batched serving,\n\
         answering mid-download and upgrading to full precision."
    );
    Ok(())
}
