//! End-to-end serving driver (the DESIGN.md §6 validation run).
//!
//! Composes every layer of the system on one real workload:
//!
//!   model server (bandwidth-shaped TCP) ──► progressive client
//!        │                                        │ publishes each stage's
//!        │                                        ▼ reconstruction
//!   eval images ──► request load ──► coordinator Router + dynamic Batcher
//!                                           │ (backend executable, hot-
//!                                           ▼  swapped weights)
//!                        per-request replies tagged with the weight bits
//!
//! While the `cnn` model is still downloading at 1 MB/s, three client
//! threads keep issuing classification requests; the coordinator serves
//! them against whatever approximation has arrived. The run reports the
//! latency histogram, throughput, and how accuracy climbs as stages land.
//!
//! Run with: `cargo run --release --example serve_e2e`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use prognet::client::{ProgressiveClient, ProgressiveOptions};
use prognet::coordinator::{BatcherConfig, Router};
use prognet::eval::EvalSet;
use prognet::models::Registry;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};
use prognet::util::stats::{fmt_secs, Summary};

const MODEL: &str = "cnn";
const SPEED_MBPS: f64 = 1.0;
const LOAD_THREADS: usize = 3;

fn main() -> prognet::Result<()> {
    anyhow::ensure!(
        prognet::artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    let t0 = Instant::now();
    // --- infrastructure
    let repo = Arc::new(Repository::open_default()?);
    let server = Server::start("127.0.0.1:0", repo, ServerConfig::default())?;
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get(MODEL)?.clone();
    let eval = Arc::new(EvalSet::load_named(&manifest.dataset)?);
    let router = Arc::new(Router::new(
        engine.clone(),
        Registry::open_default()?,
        BatcherConfig::default(),
    ));

    // --- request load: fires as soon as the first stage is published
    let done = Arc::new(AtomicBool::new(false));
    let load_handles: Vec<_> = (0..LOAD_THREADS)
        .map(|worker| {
            let router = router.clone();
            let eval = eval.clone();
            let done = done.clone();
            let classes = manifest.classes;
            std::thread::spawn(move || {
                let mut lat = Summary::new();
                let mut correct_by_bits: Vec<(u32, bool)> = Vec::new();
                let mut i = worker;
                while !done.load(Ordering::Relaxed) {
                    if !router.model_ready(MODEL) {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                        continue;
                    }
                    let img = eval.image(i % eval.n).to_vec();
                    let label = eval.labels[i % eval.n] as usize;
                    match router.infer(MODEL, img) {
                        Ok(reply) => {
                            lat.add(reply.latency.as_secs_f64());
                            if let Ok(out) = reply.output {
                                let pred = out[..classes]
                                    .iter()
                                    .enumerate()
                                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                                    .map(|(j, _)| j)
                                    .unwrap();
                                correct_by_bits.push((reply.cum_bits, pred == label));
                            }
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(2)),
                    }
                    i += LOAD_THREADS;
                }
                (lat, correct_by_bits)
            })
        })
        .collect();

    // --- progressive download publishing into the router
    let session = ModelSession::load_batches(&engine, &manifest, &[1, 32])?;
    let mut opts = ProgressiveOptions::concurrent(MODEL);
    opts.request = opts.request.with_speed(SPEED_MBPS);
    let client = ProgressiveClient::new(server.addr());

    // wire publishing through the stage results: reuse fetch_and_infer on a
    // tiny probe batch, publishing each stage's weights as they complete.
    let probe = eval.image_batch(1).to_vec();
    println!(
        "downloading '{MODEL}' at {SPEED_MBPS} MB/s while serving requests on {LOAD_THREADS} threads…"
    );
    let outcome = {
        // A custom loop: use the Assembler-level API so we can publish.
        use prognet::client::{Assembler, Downloader};
        use prognet::format::ParserEvent;
        use prognet::server::FetchRequest;
        let mut dl = Downloader::connect(
            &server.addr(),
            &FetchRequest::new(MODEL).with_speed(SPEED_MBPS),
        )?;
        let mut asm: Option<Assembler> = None;
        let mut stage_times = Vec::new();
        while !dl.is_done() {
            for te in dl.next_events()? {
                match te.event {
                    ParserEvent::Manifest(m) => asm = Some(Assembler::new(*m)),
                    ParserEvent::Fragment {
                        stage,
                        tensor,
                        payload,
                    } => {
                        let a = asm.as_mut().unwrap();
                        if let Some(done_stage) = a.absorb(stage, tensor, &payload)? {
                            let cum = a.cum_bits();
                            a.reconstruct()?;
                            router.publish_weights(MODEL, a.flat(), cum)?;
                            stage_times.push((done_stage, cum, te.t));
                            println!(
                                "  stage {done_stage} ({cum:>2} bits) published at {}",
                                fmt_secs(te.t)
                            );
                        }
                    }
                }
            }
        }
        (stage_times, dl.bytes_received(), dl.elapsed())
    };
    let _ = (client, session, opts, probe); // the simple API path is exercised in quickstart

    // let the tail of the request load run against the final model
    std::thread::sleep(std::time::Duration::from_millis(300));
    done.store(true, Ordering::Relaxed);

    let mut lat_all = Summary::new();
    let mut by_bits: std::collections::BTreeMap<u32, (usize, usize)> = Default::default();
    for h in load_handles {
        let (lat, correct) = h.join().unwrap();
        for s in lat.samples() {
            lat_all.add(*s);
        }
        for (bits, ok) in correct {
            let e = by_bits.entry(bits).or_insert((0, 0));
            e.0 += ok as usize;
            e.1 += 1;
        }
    }

    let (stages, bytes, transfer_secs) = outcome;
    println!("\n=== serve_e2e report ===");
    println!(
        "transfer: {} bytes in {} ({} stages)",
        bytes,
        fmt_secs(transfer_secs),
        stages.len()
    );
    println!(
        "requests: {} served | throughput {:.1} req/s | latency mean {} p50 {} p99 {}",
        lat_all.n(),
        lat_all.n() as f64 / t0.elapsed().as_secs_f64(),
        fmt_secs(lat_all.mean()),
        fmt_secs(lat_all.median()),
        fmt_secs(lat_all.p99()),
    );
    println!("accuracy of served replies by weight precision:");
    for (bits, (ok, total)) in &by_bits {
        println!(
            "  {bits:>2} bits: {:>5.1}% of {total} requests",
            *ok as f64 / *total as f64 * 100.0
        );
    }
    anyhow::ensure!(lat_all.n() > 0, "no requests served");
    let (_, (ok, total)) = by_bits.iter().next_back().unwrap();
    let final_acc = *ok as f64 / *total as f64;
    anyhow::ensure!(
        final_acc > 0.8,
        "final-precision serving accuracy too low: {final_acc:.2}"
    );
    println!("\nOK — all layers composed: shaped transport → progressive\n\
              reconstruction → hot-swapped weights → batched PJRT serving.");
    Ok(())
}
