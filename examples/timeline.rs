//! Fig 4 reproduction: execution timelines of singleton vs progressive
//! transmission with and without concurrent inference, rendered as ASCII
//! lanes (legend: `=` transfer, `r` reconstruct, `I` inference,
//! `*` output shown).
//!
//! Run with: `cargo run --release --example timeline`

use prognet::eval::{harness, EvalSet};
use prognet::models::Registry;
use prognet::netsim::LinkSpec;
use prognet::quant::Schedule;
use prognet::runtime::Engine;
use prognet::util::stats::fmt_secs;

fn main() -> prognet::Result<()> {
    anyhow::ensure!(
        prognet::artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get("cnn")?;
    let eval = EvalSet::load_named(&manifest.dataset)?;
    let sched = Schedule::paper_default();
    let link = LinkSpec::mbps(0.25);

    let row = harness::run_exec_time(&engine, manifest, &eval, 32, &sched, link)?;

    println!("Fig 4 — timelines for '{}' at 0.25 MB/s (32-image workload)\n", row.model);
    println!(
        "singleton:               total {}",
        fmt_secs(row.singleton)
    );
    println!(
        "progressive w/o concur.: total {} ({:+.0}%)",
        fmt_secs(row.progressive_serial),
        (row.progressive_serial / row.singleton - 1.0) * 100.0
    );
    println!(
        "progressive w/ concur.:  total {} ({:+.0}%), first output {}\n",
        fmt_secs(row.progressive_concurrent),
        (row.progressive_concurrent / row.singleton - 1.0) * 100.0,
        fmt_secs(row.first_output)
    );

    println!("-- progressive, w/o concurrent execution ('=' transfer pauses during 'r'+'I'):");
    print!("{}", row.timeline_serial.render_ascii(100));
    println!();
    println!("-- progressive, concurrent execution (§III-C — transfer never pauses):");
    print!("{}", row.timeline_concurrent.render_ascii(100));
    Ok(())
}
