//! Fig 4 reproduction: execution timelines of singleton vs progressive
//! transmission with and without concurrent inference, rendered as ASCII
//! lanes (legend: `=` transfer, `r` reconstruct, `I` inference,
//! `*` output shown).
//!
//! Run with: `cargo run --release --example timeline`

use prognet::eval::{harness, EvalSet};
use prognet::models::Registry;
use prognet::netsim::LinkSpec;
use prognet::quant::Schedule;
use prognet::runtime::Engine;
use prognet::testutil::fixture;
use prognet::util::stats::fmt_secs;

fn main() -> prognet::Result<()> {
    let engine = Engine::global()?;
    let (registry, model) = if prognet::artifacts_available() {
        (Registry::open_default()?, "cnn")
    } else {
        println!("artifacts not built — timing a synthetic fixture model instead");
        (fixture::executable_models_big("example-timeline")?, "dense2b")
    };
    let manifest = registry.get(model)?;
    let eval = if prognet::artifacts_available() {
        EvalSet::load_named(&manifest.dataset)?
    } else {
        fixture::synthetic_eval(manifest, 32, 13)
    };
    let sched = Schedule::paper_default();
    let link = LinkSpec::mbps(0.25);

    let row = harness::run_exec_time(&engine, manifest, &eval, 32, &sched, link)?;

    println!("Fig 4 — timelines for '{}' at 0.25 MB/s (32-image workload)\n", row.model);
    println!(
        "singleton:               total {}",
        fmt_secs(row.singleton)
    );
    println!(
        "progressive w/o concur.: total {} ({:+.0}%)",
        fmt_secs(row.progressive_serial),
        (row.progressive_serial / row.singleton - 1.0) * 100.0
    );
    println!(
        "progressive w/ concur.:  total {} ({:+.0}%), first output {}\n",
        fmt_secs(row.progressive_concurrent),
        (row.progressive_concurrent / row.singleton - 1.0) * 100.0,
        fmt_secs(row.first_output)
    );

    println!("-- progressive, w/o concurrent execution ('=' transfer pauses during 'r'+'I'):");
    print!("{}", row.timeline_serial.render_ascii(100));
    println!();
    println!("-- progressive, concurrent execution (§III-C — transfer never pauses):");
    print!("{}", row.timeline_concurrent.render_ascii(100));
    Ok(())
}
