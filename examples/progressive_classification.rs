//! Fig 5 reproduction: intermediate classification results during
//! transmission of the `cnn` shapes10 classifier (stands in for the
//! paper's MobileNetV2/ImageNet demo at 1.0 MB/s).
//!
//! For a handful of eval images, prints the model's predicted class and
//! confidence at every progressive stage alongside the arrival time —
//! the textual equivalent of the paper's Fig 5 strip — by walking a
//! `ProgressiveSession`'s `Inference` events. Falls back to a synthetic
//! fixture model when the artifacts are not built (the predictions are
//! then meaningless, but the event flow is identical).
//!
//! Run with: `cargo run --release --example progressive_classification`

use std::sync::Arc;

use prognet::client::{ProgressiveSession, SessionEvent};
use prognet::eval::EvalSet;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};
use prognet::testutil::fixture;

fn softmax(row: &[f32]) -> Vec<f32> {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|v| v / z).collect()
}

fn main() -> prognet::Result<()> {
    let (repo, model) = if prognet::artifacts_available() {
        (Arc::new(Repository::open_default()?), "cnn")
    } else {
        println!("artifacts not built — streaming a synthetic fixture model instead");
        let reg = fixture::executable_models("example-classify")?;
        (Arc::new(Repository::new(reg)), "dense3")
    };
    let server = Server::start("127.0.0.1:0", repo.clone(), ServerConfig::default())?;
    let engine = Engine::global()?;
    let manifest = repo.registry().get(model)?.clone();
    let session = Arc::new(ModelSession::load_batches(&engine, &manifest, &[32])?);
    let eval = if prognet::artifacts_available() {
        EvalSet::load_named(&manifest.dataset)?
    } else {
        fixture::synthetic_eval(&manifest, 8, 11)
    };

    let n = 6; // the Fig 5 strip shows a handful of examples
    let images = eval.image_batch(n).to_vec();

    // paper configuration: 1.0 MB/s transmission
    let live = ProgressiveSession::builder(model)
        .addr(server.addr())
        .speed_mbps(1.0)
        .runtime(model, session)
        .workload(images, n)
        .start()?;

    println!("Progressive image classification ({model} @ 1.0 MB/s)");
    println!("ground truth:");
    for i in 0..n {
        print!("  img{}={}", i, eval.classes[eval.labels[i] as usize]);
    }
    println!("\n");
    println!("{:<6} {:<5} {:<9} predictions (class p)", "stage", "bits", "t");
    for ev in live.events() {
        let SessionEvent::Inference { result: r, .. } = ev else {
            continue;
        };
        print!(
            "{:<6} {:<5} {:<9.2}",
            r.stage + 1,
            r.cum_bits,
            r.t_output_ready
        );
        for i in 0..n {
            let probs = softmax(&r.output.row(i)[..manifest.classes]);
            let (cls, p) = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let name = &eval.classes[cls];
            let mark = if cls == eval.labels[i] as usize { "+" } else { " " };
            print!(" {mark}{name:<9}{p:>4.2}");
        }
        println!();
    }
    live.finish()?;
    println!(
        "\n(paper Fig 5: 2-4 bit outputs are unusable, 6-bit starts being\n \
         right, 8+ bits match the final model — same pattern above)"
    );
    Ok(())
}
