//! Fig 5 reproduction: intermediate classification results during
//! transmission of the `cnn` shapes10 classifier (stands in for the
//! paper's MobileNetV2/ImageNet demo at 1.0 MB/s).
//!
//! For a handful of eval images, prints the model's predicted class and
//! confidence at every progressive stage alongside the arrival time —
//! the textual equivalent of the paper's Fig 5 strip.
//!
//! Run with: `cargo run --release --example progressive_classification`

use std::sync::Arc;

use prognet::client::{ProgressiveClient, ProgressiveOptions};
use prognet::eval::EvalSet;
use prognet::models::Registry;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};

fn softmax(row: &[f32]) -> Vec<f32> {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|v| v / z).collect()
}

fn main() -> prognet::Result<()> {
    anyhow::ensure!(
        prognet::artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    let repo = Arc::new(Repository::open_default()?);
    let server = Server::start("127.0.0.1:0", repo, ServerConfig::default())?;
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get("cnn")?;
    let session = ModelSession::load_batches(&engine, manifest, &[32])?;
    let eval = EvalSet::load_named(&manifest.dataset)?;

    let n = 6; // the Fig 5 strip shows a handful of examples
    let images = eval.image_batch(n).to_vec();

    // paper configuration: 1.0 MB/s transmission
    let mut opts = ProgressiveOptions::concurrent("cnn");
    opts.request = opts.request.with_speed(1.0);
    let client = ProgressiveClient::new(server.addr());
    let outcome = client.fetch_and_infer(&opts, &session, &images, n)?;

    println!("Progressive image classification (cnn @ 1.0 MB/s)");
    println!("ground truth:");
    for i in 0..n {
        print!("  img{}={}", i, eval.classes[eval.labels[i] as usize]);
    }
    println!("\n");
    println!("{:<6} {:<5} {:<9} predictions (class p)", "stage", "bits", "t");
    for r in &outcome.results {
        print!(
            "{:<6} {:<5} {:<9.2}",
            r.stage + 1,
            r.cum_bits,
            r.t_output_ready
        );
        for i in 0..n {
            let probs = softmax(&r.output.row(i)[..manifest.classes]);
            let (cls, p) = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let name = &eval.classes[cls];
            let mark = if cls == eval.labels[i] as usize { "+" } else { " " };
            print!(" {mark}{name:<9}{p:>4.2}");
        }
        println!();
    }
    println!(
        "\n(paper Fig 5: 2-4 bit outputs are unusable, 6-bit starts being\n \
         right, 8+ bits match the final model — same pattern above)"
    );
    Ok(())
}
