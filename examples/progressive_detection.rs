//! Fig 6 reproduction: intermediate object-detection results during
//! transmission of the `detector` boxfind model (stands in for the
//! paper's SSD-MobileNetV2/COCO demo at 2.5 MB/s).
//!
//! Renders, per stage, the predicted box against ground truth as a small
//! ASCII canvas plus IoU — the textual Fig 6.
//!
//! Run with: `cargo run --release --example progressive_detection`

use std::sync::Arc;

use prognet::client::{ProgressiveSession, SessionEvent};
use prognet::eval::{iou_cxcywh, EvalSet};
use prognet::models::Registry;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};

const W: usize = 24;
const H: usize = 12;

fn render(truth: &[f32], pred: &[f32]) -> Vec<String> {
    let mut canvas = vec![vec![b'.'; W]; H];
    let draw = |canvas: &mut Vec<Vec<u8>>, b: &[f32], ch: u8| {
        let x0 = (((b[0] - b[2] / 2.0).max(0.0)) * W as f32) as usize;
        let x1 = (((b[0] + b[2] / 2.0).min(1.0)) * (W - 1) as f32) as usize;
        let y0 = (((b[1] - b[3] / 2.0).max(0.0)) * H as f32) as usize;
        let y1 = (((b[1] + b[3] / 2.0).min(1.0)) * (H - 1) as f32) as usize;
        for x in x0..=x1.min(W - 1) {
            canvas[y0][x] = ch;
            canvas[y1.min(H - 1)][x] = ch;
        }
        for row in canvas.iter_mut().take(y1.min(H - 1) + 1).skip(y0) {
            row[x0] = ch;
            row[x1.min(W - 1)] = ch;
        }
    };
    draw(&mut canvas, truth, b'#');
    draw(&mut canvas, pred, b'o');
    canvas
        .into_iter()
        .map(|r| String::from_utf8(r).unwrap())
        .collect()
}

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        // detection needs the trained `detector` + boxfind artifacts; the
        // synthetic fixtures are classification-only
        println!("artifacts not built — skipping the detection demo (run `make artifacts`)");
        return Ok(());
    }
    let repo = Arc::new(Repository::open_default()?);
    let server = Server::start("127.0.0.1:0", repo, ServerConfig::default())?;
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get("detector")?;
    let session = Arc::new(ModelSession::load_batches(&engine, manifest, &[1])?);
    let eval = EvalSet::load_named(&manifest.dataset)?;

    let img_idx = 0;
    let images = eval.image(img_idx).to_vec();

    // paper configuration: 2.5 MB/s transmission
    let live = ProgressiveSession::builder("detector")
        .addr(server.addr())
        .speed_mbps(2.5)
        .runtime("detector", session)
        .workload(images, 1)
        .start()?;

    let truth_box = eval.box_of(img_idx);
    let truth_cls = eval.labels[img_idx] as usize;
    println!(
        "Progressive object detection (detector @ 2.5 MB/s)\n\
         ground truth: {} at (cx={:.2}, cy={:.2}, w={:.2}, h={:.2})\n\
         legend: # = ground truth, o = prediction\n",
        eval.classes[truth_cls], truth_box[0], truth_box[1], truth_box[2], truth_box[3]
    );
    let results: Vec<_> = live
        .events()
        .filter_map(|ev| match ev {
            SessionEvent::Inference { result, .. } => Some(result),
            _ => None,
        })
        .collect();
    live.finish()?;
    for r in &results {
        let row = r.output.row(0);
        let cls = r.output.argmax_class(0, manifest.classes);
        let pred_box = &row[manifest.classes..manifest.classes + 4];
        let iou = iou_cxcywh(pred_box, truth_box);
        println!(
            "stage {} ({:>2} bits, t={:.2}s): class={}{} IoU={:.2}",
            r.stage + 1,
            r.cum_bits,
            r.t_output_ready,
            eval.classes[cls],
            if cls == truth_cls { " ✓" } else { "" },
            iou
        );
        for line in render(truth_box, pred_box) {
            println!("    {line}");
        }
        println!();
    }
    Ok(())
}
