//! Quickstart: the full progressive-transmission loop in ~40 lines.
//!
//! Starts an in-process model server, progressively fetches the trained
//! `cnn` classifier over a bandwidth-shaped loopback connection, and runs
//! inference on a few evaluation images at every transmission stage —
//! printing the approximate predictions as they improve (Fig 1 of the
//! paper, end to end).
//!
//! Run with: `cargo run --release --example quickstart`
//!
//! ## Picking an inference backend
//!
//! Inference goes through `prognet::runtime::Engine`, which wraps one of
//! the pluggable backends:
//!
//! - `reference` (default) — pure-Rust interpreter; needs no native deps.
//! - `pjrt` — XLA/PJRT CPU client for the AOT HLO artifacts; requires
//!   building with `--features pjrt` against a real `xla` crate.
//!
//! Select one with the `PROGNET_BACKEND` environment variable
//! (`PROGNET_BACKEND=pjrt cargo run --release --features pjrt --example
//! quickstart`), or construct explicitly in code:
//! `Engine::reference()`, `Engine::named("pjrt")`. `Engine::global()`
//! reads `PROGNET_BACKEND` once and shares the backend process-wide.

use std::sync::Arc;

use prognet::client::{ProgressiveClient, ProgressiveOptions};
use prognet::eval::{top1, EvalSet};
use prognet::models::Registry;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};
use prognet::util::stats::{fmt_bytes, fmt_secs};

fn main() -> prognet::Result<()> {
    anyhow::ensure!(
        prognet::artifacts_available(),
        "artifacts not built — run `make artifacts` first"
    );
    // 1. Server side: repository of progressively encoded models.
    let repo = Arc::new(Repository::open_default()?);
    let server = Server::start("127.0.0.1:0", repo, ServerConfig::default())?;
    println!("server up on {}", server.addr());

    // 2. Client side: compiled executable + eval workload. The engine
    // honours PROGNET_BACKEND (reference interpreter unless overridden).
    let engine = Engine::global()?;
    println!("inference backend: {}", engine.backend_name());
    let registry = Registry::open_default()?;
    let manifest = registry.get("cnn")?;
    let session = ModelSession::load_batches(&engine, manifest, &[32])?;
    let eval = EvalSet::load_named(&manifest.dataset)?;
    let n = 32;
    let images = eval.image_batch(n).to_vec();

    // 3. Progressive fetch at 2 MB/s with concurrent inference (§III-C).
    let mut opts = ProgressiveOptions::concurrent("cnn");
    opts.request = opts.request.with_speed(2.0);
    let client = ProgressiveClient::new(server.addr());
    let outcome = client.fetch_and_infer(&opts, &session, &images, n)?;

    println!("\nstage  bits  transfer   output    top-1 on {n} images");
    for r in &outcome.results {
        let acc = top1(&r.output, &eval.labels[..n], manifest.classes);
        println!(
            "  {}    {:>2}   {:>8}  {:>8}   {:>5.1}%",
            r.stage,
            r.cum_bits,
            fmt_secs(r.t_transfer_done),
            fmt_secs(r.t_output_ready),
            acc * 100.0
        );
    }
    println!(
        "\ntransfer {} in {} | total (with 8 intermediate inferences) {}",
        fmt_bytes(outcome.bytes),
        fmt_secs(outcome.t_transfer_complete),
        fmt_secs(outcome.t_total),
    );
    println!("concurrent overhead vs pure transfer: {:+.1}%",
        (outcome.t_total / outcome.t_transfer_complete - 1.0) * 100.0);
    Ok(())
}
