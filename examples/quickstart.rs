//! Quickstart: the full progressive-transmission loop in ~50 lines.
//!
//! Starts an in-process model server, opens a `ProgressiveSession` that
//! progressively fetches a classifier over a bandwidth-shaped loopback
//! connection, and walks the typed event stream — printing the
//! approximate predictions as they improve (Fig 1 of the paper, end to
//! end). With the Python-built artifacts present it streams the trained
//! `cnn`; without them it falls back to a synthetic fixture model so the
//! demo (and the CI smoke job) runs everywhere.
//!
//! Run with: `cargo run --release --example quickstart`
//!
//! ## Picking an inference backend
//!
//! Inference goes through `prognet::runtime::Engine`, which wraps one of
//! the pluggable backends:
//!
//! - `reference` (default) — pure-Rust interpreter; needs no native deps.
//! - `pjrt` — XLA/PJRT CPU client for the AOT HLO artifacts; requires
//!   building with `--features pjrt` against a real `xla` crate.
//!
//! Select one with the `PROGNET_BACKEND` environment variable
//! (`PROGNET_BACKEND=pjrt cargo run --release --features pjrt --example
//! quickstart`), or construct explicitly in code:
//! `Engine::reference()`, `Engine::named("pjrt")`. `Engine::global()`
//! reads `PROGNET_BACKEND` once and shares the backend process-wide.

use std::sync::Arc;

use prognet::client::{ProgressiveSession, SessionEvent};
use prognet::eval::{top1, EvalSet};
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};
use prognet::testutil::fixture;
use prognet::util::stats::{fmt_bytes, fmt_secs};

fn main() -> prognet::Result<()> {
    // 1. Server side: repository of progressively encoded models.
    let (repo, model) = if prognet::artifacts_available() {
        (Arc::new(Repository::open_default()?), "cnn")
    } else {
        println!("artifacts not built — streaming a synthetic fixture model instead");
        let reg = fixture::executable_models("example-quickstart")?;
        (Arc::new(Repository::new(reg)), "dense3")
    };
    let server = Server::start("127.0.0.1:0", repo.clone(), ServerConfig::default())?;
    println!("server up on {}", server.addr());

    // 2. Client side: compiled executable + eval workload. The engine
    // honours PROGNET_BACKEND (reference interpreter unless overridden).
    let engine = Engine::global()?;
    println!("inference backend: {}", engine.backend_name());
    let manifest = repo.registry().get(model)?.clone();
    let session = Arc::new(ModelSession::load_batches(&engine, &manifest, &[32])?);
    let eval = if prognet::artifacts_available() {
        EvalSet::load_named(&manifest.dataset)?
    } else {
        fixture::synthetic_eval(&manifest, 32, 7)
    };
    let n = 32;
    let images = eval.image_batch(n).to_vec();

    // 3. Progressive session at 2 MB/s with concurrent inference
    // (§III-C): one builder, then a typed event stream.
    let live = ProgressiveSession::builder(model)
        .addr(server.addr())
        .speed_mbps(2.0)
        .runtime(model, session)
        .workload(images, n)
        .start()?;

    println!("\nstage  bits  transfer   output    top-1 on {n} images");
    let mut summary = None;
    while let Some(ev) = live.next_event() {
        match ev {
            SessionEvent::Inference { result: r, .. } => {
                let acc = top1(&r.output, &eval.labels[..n], manifest.classes);
                println!(
                    "  {}    {:>2}   {:>8}  {:>8}   {:>5.1}%",
                    r.stage,
                    r.cum_bits,
                    fmt_secs(r.t_transfer_done),
                    fmt_secs(r.t_output_ready),
                    acc * 100.0
                );
            }
            SessionEvent::Finished(s) => summary = Some(s),
            _ => {}
        }
    }
    let report = live.finish()?;
    let s = summary.expect("Finished is always emitted");
    anyhow::ensure!(report.results.len() == 8, "expected 8 stage results");

    println!(
        "\ntransfer {} in {} | total (with 8 intermediate inferences) {}",
        fmt_bytes(s.bytes),
        fmt_secs(s.t_transfer_complete),
        fmt_secs(s.t_total),
    );
    println!(
        "concurrent overhead vs pure transfer: {:+.1}%",
        (s.t_total / s.t_transfer_complete - 1.0) * 100.0
    );
    Ok(())
}
