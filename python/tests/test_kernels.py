"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes/schedules; assert_allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dequant as pk_dequant
from compile.kernels import matmul as pk_matmul
from compile.kernels import quantize as pk_quantize
from compile.kernels import ref


def _tensor(seed, n):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 0.5, size=n).astype(np.float32)


# --------------------------------------------------------------------- dequant

@pytest.mark.parametrize("n", [1, 7, 100, 16384, 16385, 50000])
def test_dequant_matches_ref_sizes(n):
    rng = np.random.default_rng(n)
    q = rng.integers(0, 2**16, size=n).astype(np.uint32)
    scale, lo, half = 3.1e-5, -0.47, 0.5
    out = pk_dequant.dequant(jnp.asarray(q), scale, lo, half)
    expect = ref.dequantize_jnp(jnp.asarray(q), scale, lo, half)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("block", [128, 1024, 16384])
def test_dequant_block_invariance(block):
    """Block size is a pure perf knob — results must be identical."""
    q = np.random.default_rng(0).integers(0, 2**16, size=3000).astype(np.uint32)
    a = pk_dequant.dequant(jnp.asarray(q), 1e-4, 0.0, 0.5, block=block)
    b = ref.dequantize_jnp(jnp.asarray(q), 1e-4, 0.0, 0.5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(
    n=st.integers(1, 3000),
    stages=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
@settings(max_examples=25, deadline=None)
def test_hypothesis_concat_dequant_fused(n, stages, seed):
    """Fused Eq. 4+5 kernel == oracle for arbitrary sizes / stage counts."""
    widths = [2] * 8
    m = _tensor(seed, n)
    lo, hi = ref.qparams(m)
    if hi <= lo:
        return
    q = ref.quantize_np(m)
    parts = [jnp.asarray(p) for p in ref.split_np(q, widths)[:stages]]
    cum = sum(widths[:stages])
    scale = (hi - lo) / 2**16
    half = float(2 ** (16 - cum - 1)) if cum < 16 else 0.5
    out = pk_dequant.concat_dequant(parts, widths[:stages], scale, lo, half)
    expect = ref.concat_dequant_jnp(parts, widths[:stages], scale, lo, half)
    # atol covers FMA-contraction differences between the pallas interpret
    # path and the jnp oracle (~1 ulp of the pre-add magnitude)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6, atol=5e-7)
    # and the reconstruction is within the analytic bound of the original
    assert np.max(np.abs(np.asarray(out) - m)) <= ref.roundtrip_error_bound(lo, hi, cum)


# -------------------------------------------------------------------- quantize

@pytest.mark.parametrize("n", [1, 129, 16384, 20000])
def test_quantize_kernel_matches_jnp_oracle(n):
    m = _tensor(n, n)
    lo, hi = ref.qparams(m)
    out = pk_quantize.quantize(jnp.asarray(m), lo, hi)
    expect = ref.quantize_jnp(jnp.asarray(m), lo, hi)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@given(seed=st.integers(0, 2**31), n=st.integers(1, 2000))
@settings(max_examples=20, deadline=None)
def test_hypothesis_quantize_close_to_f64_encoder(seed, n):
    """f32 kernel vs f64 canonical encoder: off by at most 1 code."""
    m = _tensor(seed, n)
    lo, hi = ref.qparams(m)
    if hi <= lo:
        return
    q32 = np.asarray(pk_quantize.quantize(jnp.asarray(m), lo, hi)).astype(np.int64)
    q64 = ref.quantize_np(m).astype(np.int64)
    assert np.max(np.abs(q32 - q64)) <= 1


@pytest.mark.parametrize("widths", [[2] * 8, [4] * 4, [8, 8], [1, 1, 2, 4, 8], [16]])
def test_split_kernel_matches_ref(widths):
    q = np.random.default_rng(5).integers(0, 2**16, size=4097).astype(np.uint32)
    outs = pk_quantize.bitplane_split(jnp.asarray(q), widths)
    expect = ref.split_np(q, widths)
    for a, b in zip(outs, expect):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_split_then_fused_dequant_roundtrip():
    m = _tensor(77, 9999)
    lo, hi = ref.qparams(m)
    q = ref.quantize_np(m)
    widths = [2] * 8
    parts = pk_quantize.bitplane_split(jnp.asarray(q), widths)
    out = pk_dequant.concat_dequant(parts, widths, (hi - lo) / 2**16, lo, 0.5)
    expect = ref.dequantize_np(q, lo, hi, 16)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------- matmul

@pytest.mark.parametrize(
    "m,k,n", [(1, 1, 1), (8, 64, 32), (70, 200, 33), (128, 128, 128), (130, 257, 129)]
)
def test_matmul_matches_oracle(m, k, n):
    rng = np.random.default_rng(m * 1000 + n)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    out = pk_matmul.matmul(jnp.asarray(a), jnp.asarray(b))
    expect = ref.matmul_jnp(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-5, atol=2e-5)


@given(
    m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
    seed=st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_hypothesis_matmul_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    out = pk_matmul.matmul(jnp.asarray(a), jnp.asarray(b), tm=32, tn=32, tk=32)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=3e-5, atol=3e-5)
