"""AOT lowering contract tests: HLO text shape/parameter layout that the
rust runtime depends on (no training required — structural checks)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text, pack_plane_np, DEFAULT_SCHEDULE
from compile.kernels import ref


def test_hlo_text_is_parseable_hlo_module():
    def f(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(f).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple (rust calls to_tuple1)
    assert "tuple(" in text.replace(" ", "")[:20000] or "(f32[4,4]" in text


@pytest.mark.parametrize("name", ["mlp", "cnn"])
def test_fwd_lowering_params_and_output(name):
    spec = model.ARCHS[name]["spec"]
    x = jax.ShapeDtypeStruct((2, 32, 32, 3), jnp.float32)
    f = jax.ShapeDtypeStruct((spec.total,), jnp.float32)
    text = to_hlo_text(jax.jit(model.fwd(name)).lower(x, f))
    # exactly two parameters with the documented shapes
    assert f"f32[2,32,32,3]" in text
    assert f"f32[{spec.total}]" in text
    # classifier output: batch x 10 logits
    assert "f32[2,10]" in text


def test_qfwd_lowering_has_five_params_and_u32_codes():
    name = "cnn"
    spec = model.ARCHS[name]["spec"]
    ntens = len(spec.entries)
    x = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
    q = jax.ShapeDtypeStruct((spec.total,), jnp.uint32)
    s = jax.ShapeDtypeStruct((ntens,), jnp.float32)
    h = jax.ShapeDtypeStruct((1,), jnp.float32)
    text = to_hlo_text(jax.jit(model.qfwd(name)).lower(x, q, s, s, h))
    assert f"u32[{spec.total}]" in text, "quantized codes must be u32"
    assert f"f32[{ntens}]" in text, "per-tensor scale/min vectors"


def test_default_schedule_is_paper_schedule():
    assert DEFAULT_SCHEDULE == [2] * 8
    assert sum(DEFAULT_SCHEDULE) == ref.K


def test_pack_plane_agrees_with_split_masks():
    rng = np.random.default_rng(3)
    q = rng.integers(0, 2**16, size=257).astype(np.uint32)
    parts = ref.split_np(q, DEFAULT_SCHEDULE)
    # stage-0 plane holds the top 2 bits MSB-first: reconstruct manually
    packed = pack_plane_np(parts[0], 2)
    first_byte = packed[0]
    expect = (
        ((q[0] >> 14) & 3) << 6
        | ((q[1] >> 14) & 3) << 4
        | ((q[2] >> 14) & 3) << 2
        | ((q[3] >> 14) & 3)
    )
    assert first_byte == expect


def test_qfwd_progressive_monotone_quality():
    """Flat-interface contract: truncated codes through qfwd degrade
    gracefully and improve with more bits (tiny random model)."""
    name = "mlp"
    spec = model.ARCHS[name]["spec"]
    flat = spec.flatten_np(model.init_params(name, 9))
    qflat = np.zeros(spec.total, np.uint32)
    scales, los = [], []
    for (_, shape), off in zip(spec.entries, spec.offsets):
        n = int(np.prod(shape))
        seg = flat[off : off + n]
        lo, hi = ref.qparams(seg)
        qflat[off : off + n] = ref.quantize_np(seg)
        scales.append((hi - lo) / 2**16)
        los.append(lo)
    x = jnp.asarray(np.random.default_rng(0).uniform(size=(2, 32, 32, 3)).astype(np.float32))
    (ref_out,) = jax.jit(model.fwd(name))(x, jnp.asarray(flat))

    fn = jax.jit(model.qfwd(name))
    errs = []
    for cum in [4, 8, 16]:
        if cum < 16:
            trunc = (qflat >> (16 - cum)) << (16 - cum)
            half = float(2 ** (16 - cum - 1))
        else:
            trunc, half = qflat, 0.5
        (out,) = fn(
            x,
            jnp.asarray(trunc),
            jnp.asarray(np.array(scales, np.float32)),
            jnp.asarray(np.array(los, np.float32)),
            jnp.asarray(np.array([half], np.float32)),
        )
        errs.append(float(jnp.max(jnp.abs(out - ref_out))))
    assert errs[2] <= errs[1] <= errs[0] * 1.5, errs
    assert errs[2] < 5e-3, errs
