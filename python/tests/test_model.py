"""L2 model definitions: shapes, flat-layout, fwd/qfwd equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, model
from compile.kernels import ref


@pytest.mark.parametrize("name", list(model.ARCHS))
def test_spec_flat_layout(name):
    spec = model.ARCHS[name]["spec"]
    man = spec.manifest()
    # offsets are contiguous and ordered
    off = 0
    for t in man:
        assert t["offset"] == off
        assert t["numel"] == int(np.prod(t["shape"]))
        off += t["numel"]
    assert off == spec.total


@pytest.mark.parametrize("name", list(model.ARCHS))
def test_flatten_unflatten_roundtrip(name):
    spec = model.ARCHS[name]["spec"]
    params = model.init_params(name, 0)
    flat = spec.flatten_np(params)
    back = spec.unflatten(jnp.asarray(flat))
    for a, b in zip(params, back):
        np.testing.assert_array_equal(a, np.asarray(b))


@pytest.mark.parametrize("name,batch", [(n, b) for n in model.ARCHS for b in (1, 4)])
def test_fwd_output_shape(name, batch):
    spec = model.ARCHS[name]["spec"]
    flat = jnp.asarray(spec.flatten_np(model.init_params(name, 1)))
    x = jnp.zeros((batch, 32, 32, 3), jnp.float32)
    (out,) = model.fwd(name)(x, flat)
    n_out = model.ARCHS[name]["classes"] + (4 if model.ARCHS[name]["task"] == "detect" else 0)
    assert out.shape == (batch, n_out)


def test_detector_box_in_unit_range():
    flat = jnp.asarray(
        model.ARCHS["detector"]["spec"].flatten_np(model.init_params("detector", 2))
    )
    x = jnp.asarray(np.random.default_rng(0).uniform(size=(3, 32, 32, 3)).astype(np.float32))
    (out,) = model.fwd("detector")(x, flat)
    box = np.asarray(out[:, 3:])
    assert (box >= 0).all() and (box <= 1).all()


@pytest.mark.parametrize("name", ["mlp", "cnn", "detector"])
def test_qfwd_equals_fwd_at_full_bits(name):
    """qfwd(quantize(w), 16 bits) must track fwd(w) within quantization noise."""
    spec = model.ARCHS[name]["spec"]
    flat = spec.flatten_np(model.init_params(name, 3))
    qflat = np.zeros(spec.total, np.uint32)
    scales, los = [], []
    for (_, shape), off in zip(spec.entries, spec.offsets):
        n = int(np.prod(shape))
        seg = flat[off : off + n]
        lo, hi = ref.qparams(seg)
        qflat[off : off + n] = ref.quantize_np(seg)
        scales.append((hi - lo) / 2**16)
        los.append(lo)
    x = jnp.asarray(np.random.default_rng(1).uniform(size=(2, 32, 32, 3)).astype(np.float32))
    (a,) = jax.jit(model.fwd(name))(x, jnp.asarray(flat))
    (b,) = jax.jit(model.qfwd(name))(
        x,
        jnp.asarray(qflat),
        jnp.asarray(np.array(scales, np.float32)),
        jnp.asarray(np.array(los, np.float32)),
        jnp.asarray(np.array([0.5], np.float32)),
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_loss_decreases_smoke():
    """A few Adam steps must reduce classification loss (training sanity)."""
    from compile import train

    x, y = datasets.shapes10(64, 42)
    spec = model.ARCHS["mlp"]["spec"]
    flat = jnp.asarray(spec.flatten_np(model.init_params("mlp", 4)))
    loss = model.loss_fn("mlp")
    step = train.adam_step(1e-3)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    l0 = None
    xs, ys = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def upd(i, flat, m, v):
        l, g = jax.value_and_grad(loss)(flat, xs, ys)
        flat, m, v = step(i, flat, m, v, g)
        return flat, m, v, l

    for i in range(20):
        flat, m, v, l = upd(i, flat, m, v)
        if l0 is None:
            l0 = float(l)
    assert float(l) < l0


def test_datasets_deterministic():
    a1, b1 = datasets.shapes10(16, 5)
    a2, b2 = datasets.shapes10(16, 5)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    x1, y1, z1 = datasets.boxfind(8, 6)
    x2, y2, z2 = datasets.boxfind(8, 6)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(z1, z2)


def test_datasets_ranges():
    x, y = datasets.shapes10(32, 9)
    assert x.min() >= 0 and x.max() <= 1 and x.dtype == np.float32
    assert set(np.unique(y)).issubset(set(range(10)))
    xi, yi, bi = datasets.boxfind(32, 9)
    assert (bi > 0).all() and (bi < 1).all()
