"""AOT artifact integrity — runs only if `make artifacts` has been run."""

import json
import os
import zlib

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ROOT, "models", "index.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _models():
    with open(os.path.join(ROOT, "models", "index.json")) as f:
        return [m["name"] for m in json.load(f)["models"]]


def test_index_lists_models():
    assert set(_models()) >= {"mlp", "cnn", "detector"}


@pytest.mark.parametrize("name", ["mlp", "cnn", "widecnn", "detector"])
def test_model_artifact_consistency(name):
    d = os.path.join(ROOT, "models", name)
    if not os.path.exists(d):
        pytest.skip(f"{name} not built")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    flat = np.fromfile(os.path.join(d, "weights.bin"), dtype="<f4")
    assert flat.size == man["param_count"]
    off = 0
    for t in man["tensors"]:
        assert t["offset"] == off
        seg = flat[off : off + t["numel"]]
        assert abs(float(seg.min()) - t["min"]) < 1e-6
        assert abs(float(seg.max()) - t["max"]) < 1e-6
        off += t["numel"]
    assert off == flat.size
    for key, fn in man["hlo"].items():
        path = os.path.join(d, fn)
        assert os.path.exists(path), f"missing {key}"
        head = open(path).read(200)
        assert "HloModule" in head


def test_golden_codec_vectors_selfconsistent():
    from compile.kernels import ref
    from compile.aot import pack_plane_np

    gd = os.path.join(ROOT, "golden")
    with open(os.path.join(gd, "codec.json")) as f:
        g = json.load(f)
    m = np.fromfile(os.path.join(gd, "weights.bin"), dtype="<f4")
    assert m.size == g["n"]
    q = np.fromfile(os.path.join(gd, "q16.bin"), dtype="<u4")
    np.testing.assert_array_equal(ref.quantize_np(m), q)
    assert (zlib.crc32(q.astype("<u4").tobytes()) & 0xFFFFFFFF) == g["q_crc32"]
    parts = ref.split_np(q, g["widths"])
    cum = 0
    for i, (st, w) in enumerate(zip(g["stages"], g["widths"])):
        cum += w
        packed = pack_plane_np(parts[i], w)
        assert len(packed) == st["plane_len"]
        assert (zlib.crc32(packed) & 0xFFFFFFFF) == st["plane_crc32"]
        deq = ref.dequantize_np(ref.concat_np(parts[: i + 1], g["widths"][: i + 1]),
                                g["min"], g["max"], cum)
        np.testing.assert_allclose(deq[:32], np.array(st["deq_head"], np.float32), rtol=1e-6)


def test_eval_data_artifacts():
    for ds, extra in [("shapes10", []), ("boxfind", ["boxes.bin"])]:
        d = os.path.join(ROOT, "data", ds)
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        n = man["n"]
        imgs = np.fromfile(os.path.join(d, "images.bin"), dtype="<f4")
        assert imgs.size == n * 32 * 32 * 3
        labels = np.fromfile(os.path.join(d, "labels.bin"), dtype="<i4")
        assert labels.size == n
        assert labels.min() >= 0 and labels.max() < len(man["classes"])
        for e in extra:
            assert os.path.exists(os.path.join(d, e))


def test_trained_accuracy_recorded():
    """Training must have produced usable models (the Table II baseline)."""
    d = os.path.join(ROOT, "models", "cnn")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    assert man["accuracy"]["top1"] > 0.7, man["accuracy"]
