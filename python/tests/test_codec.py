"""Codec (Eqs. 2-5) property tests: numpy reference implementation."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.aot import pack_plane_np

SCHEDULES = [
    [2, 2, 2, 2, 2, 2, 2, 2],
    [4, 4, 4, 4],
    [8, 8],
    [1, 1, 2, 4, 8],
    [16],
    [2, 6, 8],
]


def _rand_tensor(seed, n=2048, scale=1.0, offset=0.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 0.3, size=n) * scale + offset).astype(np.float32)


@pytest.mark.parametrize("seed", range(5))
def test_quantize_range(seed):
    m = _rand_tensor(seed)
    q = ref.quantize_np(m)
    assert q.dtype == np.uint32
    assert q.min() >= 0 and q.max() <= 2**16 - 1
    # max element maps to the top bucket, min to 0
    assert q[np.argmin(m)] == 0
    assert q[np.argmax(m)] == 2**16 - 1


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("seed", [0, 1])
def test_split_concat_identity(schedule, seed):
    """Eq. 4 over all planes must restore Eq. 3's input exactly."""
    m = _rand_tensor(seed)
    q = ref.quantize_np(m)
    parts = ref.split_np(q, schedule)
    assert (ref.concat_np(parts, schedule) == q).all()


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_parts_fit_width(schedule):
    q = ref.quantize_np(_rand_tensor(3))
    for p, w in zip(ref.split_np(q, schedule), schedule):
        assert p.max() < (1 << w)


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_progressive_error_decreases(schedule):
    """More received bits must never increase max reconstruction error."""
    m = _rand_tensor(7, n=4096)
    lo, hi = ref.qparams(m)
    q = ref.quantize_np(m)
    parts = ref.split_np(q, schedule)
    prev = np.inf
    cum = 0
    for i, w in enumerate(schedule):
        cum += w
        deq = ref.dequantize_np(ref.concat_np(parts[: i + 1], schedule[: i + 1]), lo, hi, cum)
        err = float(np.max(np.abs(deq - m)))
        assert err <= ref.roundtrip_error_bound(lo, hi, cum)
        assert err <= prev + 1e-7
        prev = err


def test_full_roundtrip_error_bound():
    m = _rand_tensor(11, n=8192, scale=3.0, offset=-1.0)
    lo, hi = ref.qparams(m)
    deq = ref.dequantize_np(ref.quantize_np(m), lo, hi, 16)
    # half-step revision -> max error is half a quantization step, plus
    # f32 cast slack (the reconstruction is stored in float32)
    step = (hi - lo + ref.eps_for(lo, hi)) / 2**16
    assert np.max(np.abs(deq - m)) <= step * 0.5 + abs(hi - lo) * 1e-6 + 1e-7


def test_degenerate_constant_tensor():
    m = np.full(100, 0.42, dtype=np.float32)
    q = ref.quantize_np(m)
    assert (q == 0).all()
    deq = ref.dequantize_np(q, 0.42, 0.42, 16)
    np.testing.assert_allclose(deq, m, atol=1e-6)


@given(
    data=st.lists(st.floats(-1e4, 1e4, width=32), min_size=2, max_size=300),
    cut=st.integers(1, 15),
)
@settings(max_examples=60, deadline=None)
def test_hypothesis_truncated_dequant_bound(data, cut):
    """Truncation to `cut` bits keeps error within one step at `cut` bits."""
    m = np.array(data, dtype=np.float32)
    lo, hi = ref.qparams(m)
    if hi <= lo:
        return
    q = ref.quantize_np(m)
    q_trunc = (q >> (16 - cut)) << (16 - cut)
    deq = ref.dequantize_np(q_trunc, lo, hi, cut)
    assert np.max(np.abs(deq - m)) <= ref.roundtrip_error_bound(lo, hi, cut)


@given(
    vals=st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=200),
    width=st.sampled_from([1, 2, 3, 4, 5, 6, 7, 8]),
)
@settings(max_examples=60, deadline=None)
def test_hypothesis_pack_plane_size(vals, width):
    """Packed plane is exactly ceil(n*width/8) bytes (no size inflation)."""
    v = np.array(vals, dtype=np.uint32) & ((1 << width) - 1)
    packed = pack_plane_np(v, width)
    assert len(packed) == (len(vals) * width + 7) // 8


def test_pack_plane_known_vector():
    # width=2, values 0,1,2,3 -> bits 00 01 10 11 -> byte 0b00011011 = 0x1B
    assert pack_plane_np(np.array([0, 1, 2, 3], np.uint32), 2) == b"\x1b"
    # width=4, values 0xA,0xB,0xC -> 0xAB, 0xC0
    assert pack_plane_np(np.array([0xA, 0xB, 0xC], np.uint32), 4) == b"\xab\xc0"


def test_total_size_not_increased():
    """Paper claim: progressive representation does not increase model size."""
    m = _rand_tensor(13, n=10007)
    q = ref.quantize_np(m)
    widths = [2] * 8
    total = sum(len(pack_plane_np(p, w)) for p, w in zip(ref.split_np(q, widths), widths))
    singleton = (10007 * 16 + 7) // 8
    assert total <= singleton + len(widths)  # <= one ragged byte per plane
