"""Pallas kernel for Eq. 2 (floor quantization) and Eq. 3 (bit division).

Used by the encode-path tests and the codec benches; the deployed encoder
is the rust implementation (rust/src/quant/), which is tested against the
same golden vectors these kernels are.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .dequant import _pad_to_block, BLOCK


def _quantize_kernel(k, m_ref, lo_ref, inv_ref, out_ref):
    # q = clip(floor((m - lo) * inv), 0, 2^k - 1); inv = 2^k / (hi - lo + eps)
    q = jnp.floor((m_ref[...] - lo_ref[0]) * inv_ref[0])
    q = jnp.clip(q, 0.0, float(2**k - 1))
    out_ref[...] = q.astype(jnp.uint32)


def quantize(m, lo, hi, *, k: int = ref.K, block: int = BLOCK):
    """Eq. 2 over a flat f32 vector. Returns u32 vector in [0, 2^k)."""
    m = m.reshape(-1)
    mp, n = _pad_to_block(m, block)
    lo_s = jnp.asarray(lo, jnp.float32).reshape(1)
    eps = jnp.maximum((jnp.asarray(hi) - jnp.asarray(lo)) * 1e-6, 1e-12)
    inv = (float(2**k) / (jnp.asarray(hi, jnp.float32) - lo_s + eps)).reshape(1)
    grid = mp.shape[0] // block
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, k),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(mp.shape, jnp.uint32),
        interpret=True,
    )(mp, lo_s, inv)
    return out[:n]


def _split_kernel(widths, k, q_ref, *out_refs):
    q = q_ref[...]
    cum = 0
    for o_ref, w in zip(out_refs, widths):
        cum += w
        o_ref[...] = (q >> (k - cum)) & jnp.uint32((1 << w) - 1)


def bitplane_split(q, widths, *, k: int = ref.K, block: int = BLOCK):
    """Eq. 3: split flat u32 q<k> into len(widths) fraction planes (u32)."""
    assert sum(widths) == k
    q = q.reshape(-1)
    qp, n = _pad_to_block(q, block)
    grid = qp.shape[0] // block
    outs = pl.pallas_call(
        functools.partial(_split_kernel, tuple(widths), k),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in widths],
        out_shape=[jax.ShapeDtypeStruct(qp.shape, jnp.uint32) for _ in widths],
        interpret=True,
    )(qp)
    return [o[:n] for o in outs]
