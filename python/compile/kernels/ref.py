"""Pure-jnp / numpy oracles for the ProgressiveNet codec (Eqs. 2-5).

These are the ground-truth implementations the Pallas kernels (and the rust
codec, transitively, via golden vectors emitted by aot.py) are tested
against.

Codec specification (shared with rust/src/quant/):

- k = 16 bits, unsigned.
- Eq. 2 (quantize):   q = floor(2^k * (M - min) / (max - min + eps))
  with eps = max((max - min) * 1e-6, 1e-12), arithmetic in float64.
  Degenerate tensors (max == min) quantize to all-zeros.
- Eq. 3 (bit division) for schedule widths b = [b_1..b_n], cum c_m = sum b_1..b_m:
      p<k,m> = (q << c_{m-1}) >> (k - b_m + c_{m-1})   (on k-bit words)
  i.e. part m holds bits [k - c_m, k - c_{m-1}) of q, MSB-first.
- Eq. 4 (bit concatenation): q'<k> = OR_m (p<k,m> << (k - c_m)).
- Eq. 5 (dequantize) after receiving c cumulative bits:
      M' = (max - min) * (q' + 2^{k-c-1}) / 2^k + min
  The 2^{k-c-1} term is the midpoint estimate of the unreceived low bits;
  at c == k it equals the paper's floor-loss revision (max-min)/2^{k+1}
  (the paper's Eq. 5 writes the fully-received special case).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

K = 16
Q_DTYPE = jnp.uint32


def qparams(m: np.ndarray) -> tuple[float, float]:
    """(min, max) of a tensor, as the encoder uses them (float64 exact)."""
    return float(np.min(m)), float(np.max(m))


def eps_for(lo: float, hi: float) -> float:
    return max((hi - lo) * 1e-6, 1e-12)


def quantize_np(m: np.ndarray, k: int = K) -> np.ndarray:
    """Eq. 2 in float64 numpy — the canonical encoder."""
    lo, hi = qparams(m)
    if hi <= lo:
        return np.zeros(m.shape, dtype=np.uint32)
    scale = (2.0 ** k) / (hi - lo + eps_for(lo, hi))
    q = np.floor((m.astype(np.float64) - lo) * scale)
    q = np.clip(q, 0, 2 ** k - 1)
    return q.astype(np.uint32)


def quantize_jnp(m, lo, hi, k: int = K):
    """Eq. 2 in jnp float32 (oracle for the Pallas quantize kernel).

    Note: float32 arithmetic — tested against the Pallas kernel (also f32),
    not bit-exactly against quantize_np.
    """
    eps = jnp.maximum((hi - lo) * 1e-6, 1e-12)
    scale = (2.0 ** k) / (hi - lo + eps)
    q = jnp.floor((m - lo) * scale)
    q = jnp.clip(q, 0.0, float(2 ** k - 1))
    return q.astype(Q_DTYPE)


def split_np(q: np.ndarray, widths: list[int], k: int = K) -> list[np.ndarray]:
    """Eq. 3: split the k-bit integers into len(widths) fraction planes."""
    assert sum(widths) == k, f"schedule {widths} must sum to {k}"
    parts = []
    cum = 0
    for w in widths:
        cum += w
        parts.append(((q >> (k - cum)) & ((1 << w) - 1)).astype(np.uint32))
    return parts


def concat_np(parts: list[np.ndarray], widths: list[int], k: int = K) -> np.ndarray:
    """Eq. 4: OR the first len(parts) planes back into a k-bit integer."""
    q = np.zeros(parts[0].shape, dtype=np.uint32)
    cum = 0
    for p, w in zip(parts, widths):
        cum += w
        q |= (p.astype(np.uint32) << (k - cum))
    return q


def dequantize_np(q: np.ndarray, lo: float, hi: float, cum_bits: int, k: int = K) -> np.ndarray:
    """Eq. 5 with midpoint revision for partially received bits (float32 out)."""
    half = float(2 ** (k - cum_bits - 1)) if cum_bits < k else 0.5
    scale = (hi - lo) / float(2 ** k)
    return ((q.astype(np.float64) + half) * scale + lo).astype(np.float32)


def dequantize_jnp(q, scale, lo, half):
    """Eq. 5 oracle matching the Pallas dequant kernel's contract.

    scale = (max - min) / 2^k ; half = 2^{k-c-1} (0.5 when fully received).
    """
    return (q.astype(jnp.float32) + half) * scale + lo


def concat_dequant_jnp(parts, widths, scale, lo, half, k: int = K):
    """Fused Eq. 4 + Eq. 5 oracle (matches the Pallas concat_dequant kernel)."""
    q = jnp.zeros(parts[0].shape, dtype=Q_DTYPE)
    cum = 0
    for p, w in zip(parts, widths):
        cum += w
        q = q | (p.astype(Q_DTYPE) << (k - cum))
    return dequantize_jnp(q, scale, lo, half)


def matmul_jnp(a, b):
    """Oracle for the Pallas tiled matmul kernel."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def roundtrip_error_bound(lo: float, hi: float, cum_bits: int) -> float:
    """Max |M - M'| after quantize -> truncate to cum_bits -> dequantize.

    One quantization step at cum_bits (floor error + midpoint estimate),
    plus eps: quantization scales by (hi-lo+eps) while dequantization
    scales by (hi-lo), a mismatch that matters when eps ~ range (near-
    degenerate tensors, range ~1e-12 — found by hypothesis).
    """
    if hi <= lo:
        return 1e-6
    step = (hi - lo + eps_for(lo, hi)) / (2 ** cum_bits)
    return step + eps_for(lo, hi)
