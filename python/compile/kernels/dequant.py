"""Pallas kernels for the per-stage compute hot-spot: Eq. 4 + Eq. 5.

The progressive client reconstructs float weights at every stage; this is
the paper's per-stage overhead that concurrent execution (§III-C) hides.
Two kernels:

- ``dequant``: Eq. 5 only — takes the already-OR-accumulated q'<k> plane.
  This is what the ``qfwd`` model artifacts embed (the rust client keeps
  the incremental OR-accumulator, Eq. 4, in its own hot loop).
- ``concat_dequant``: fused Eq. 4 + Eq. 5 over n fraction planes — the
  full reconstruct-from-planes path, used by the codec tests/benches.

TPU mapping (DESIGN.md §3): pure streaming elementwise pass, 1-D grid over
the flattened tensor, block = 16384 elements. Per block the kernel touches
(n+1) * 64 KiB of VMEM (u32 in, f32 out) — far below VMEM capacity, leaving
room for double buffering. Integer lanes for shift/OR, one astype + FMA at
the end; VPU-bound by design (no MXU involvement).

All kernels run ``interpret=True`` — mandatory for CPU PJRT (real TPU
lowering emits Mosaic custom-calls the CPU plugin cannot execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 16384


def _dequant_kernel(q_ref, scale_ref, lo_ref, half_ref, out_ref):
    q = q_ref[...]
    # single astype + FMA: out = (f32(q) + half) * scale + lo
    out_ref[...] = (q.astype(jnp.float32) + half_ref[0]) * scale_ref[0] + lo_ref[0]


def _pad_to_block(v, block):
    n = v.shape[0]
    pad = (-n) % block
    if pad:
        v = jnp.pad(v, (0, pad))
    return v, n


def dequant(q, scale, lo, half, *, block: int = BLOCK):
    """Eq. 5 over a flat u32 vector ``q``; scalars are rank-0/(1,) f32.

    Returns f32 vector of the same length.
    """
    q = q.reshape(-1)
    qp, n = _pad_to_block(q, block)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    lo = jnp.asarray(lo, jnp.float32).reshape(1)
    half = jnp.asarray(half, jnp.float32).reshape(1)
    grid = qp.shape[0] // block
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=True,
    )(qp, scale, lo, half)
    return out[:n]


def _concat_dequant_kernel(widths, k, *refs):
    *part_refs, scale_ref, lo_ref, half_ref, out_ref = refs
    q = jnp.zeros(part_refs[0].shape, dtype=jnp.uint32)
    cum = 0
    for p_ref, w in zip(part_refs, widths):
        cum += w
        q = q | (p_ref[...].astype(jnp.uint32) << (k - cum))
    out_ref[...] = (q.astype(jnp.float32) + half_ref[0]) * scale_ref[0] + lo_ref[0]


def concat_dequant(parts, widths, scale, lo, half, *, k: int = ref.K, block: int = BLOCK):
    """Fused Eq. 4 + Eq. 5: OR ``len(parts)`` fraction planes, dequantize.

    ``parts`` are flat u32 vectors (unpacked plane values), ``widths`` the
    matching bit-widths (python ints, static).
    """
    assert len(parts) == len(widths) and parts, "need >= 1 plane"
    flat = [p.reshape(-1) for p in parts]
    n = flat[0].shape[0]
    padded = []
    for p in flat:
        pp, _ = _pad_to_block(p, block)
        padded.append(pp)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    lo = jnp.asarray(lo, jnp.float32).reshape(1)
    half = jnp.asarray(half, jnp.float32).reshape(1)
    grid = padded[0].shape[0] // block
    kern = functools.partial(_concat_dequant_kernel, tuple(widths), k)
    out = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in padded]
        + [pl.BlockSpec((1,), lambda i: (0,)) for _ in range(3)],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(padded[0].shape, jnp.float32),
        interpret=True,
    )(*padded, scale, lo, half)
    return out[:n]
