"""Pallas tiled matmul kernel for the dense heads.

MXU-shaped 128x128 output tiles with a K-loop accumulator held in the
output block (VMEM-resident across the innermost grid dimension). On real
TPU this maps onto the systolic array with bf16 inputs; here it runs under
interpret=True (CPU) and is used by the ``qfwd`` artifacts' final dense
layer plus the kernel test/bench suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _matmul_kernel(nk, a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x, t):
    return -(-x // t) * t


def matmul(a, b, *, tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K):
    """C[M,N] = A[M,K] @ B[K,N], f32, arbitrary shapes (padded to tiles)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    tm, tn, tk = min(tm, _ceil_to(m, 8)), min(tn, _ceil_to(n, 8)), min(tk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(k, tk)
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    nk = kp // tk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk),
        grid=(mp // tm, np_ // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, l: (i, l)),
            pl.BlockSpec((tk, tn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]
