"""L1: Pallas kernels (build-time) + pure-jnp oracles.

- ``dequant``: Eq. 5 / fused Eq. 4+5 — the per-stage reconstruct hot-spot.
- ``quantize``: Eq. 2 floor quantization + Eq. 3 bit division.
- ``matmul``: MXU-tiled dense matmul for the model heads.
- ``ref``: jnp/numpy oracles and the codec specification.
"""

from . import dequant, matmul, quantize, ref  # noqa: F401
