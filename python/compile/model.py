"""L2: JAX model definitions (build-time only).

Every model exposes a *flat-parameter* forward: ``fwd(x, flat)`` where
``flat`` is the f32 concatenation of all weight tensors in manifest order.
This is the key interface for progressive inference — the rust client
reconstructs an updated ``flat`` at every transmission stage and feeds the
same compiled executable again.

Two lowered variants per model (see aot.py):
- ``fwd``  — (x, flat f32[P]) -> logits. The rust hot path: dequant runs in
  the rust codec, the executable sees plain float weights.
- ``qfwd`` — (x, qflat u32[P], scales f32[T], los f32[T], half f32[1])
  -> logits. The fused variant: the L1 Pallas dequant kernel (Eq. 5) runs
  per tensor inside the executable, and the final dense layer uses the
  L1 Pallas matmul kernel. ``scales`` = (max-min)/2^16 per tensor,
  ``half`` = 2^{16-c-1} for c cumulative received bits.

Models (DESIGN.md §2 substitutions for the paper's ImageNet/COCO zoo):
  mlp / cnn / widecnn  — shapes10 classifiers (Table II rows 2-4 stand-ins)
  detector             — boxfind single-object detector (rows 5-7 stand-in)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import dequant as pk_dequant
from .kernels import matmul as pk_matmul

IMG = 32
DIMNUM = ("NHWC", "HWIO", "NHWC")


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

class Spec:
    """An ordered list of named tensors; defines the flat layout."""

    def __init__(self, entries: list[tuple[str, tuple[int, ...]]]):
        self.entries = entries
        self.offsets = []
        off = 0
        for _, shape in entries:
            self.offsets.append(off)
            off += int(np.prod(shape))
        self.total = off

    def unflatten(self, flat):
        out = []
        for (name, shape), off in zip(self.entries, self.offsets):
            n = int(np.prod(shape))
            out.append(flat[off : off + n].reshape(shape))
        return out

    def flatten_np(self, tensors: list[np.ndarray]) -> np.ndarray:
        assert len(tensors) == len(self.entries)
        return np.concatenate([t.reshape(-1).astype(np.float32) for t in tensors])

    def manifest(self) -> list[dict]:
        return [
            {"name": n, "shape": list(s), "numel": int(np.prod(s)), "offset": off}
            for (n, s), off in zip(self.entries, self.offsets)
        ]


def _conv_spec(cin, cout, tag):
    return [(f"{tag}.w", (3, 3, cin, cout)), (f"{tag}.b", (cout,))]


def _dense_spec(cin, cout, tag):
    return [(f"{tag}.w", (cin, cout)), (f"{tag}.b", (cout,))]


ARCHS: dict[str, dict] = {
    "mlp": {
        "task": "classify",
        "classes": 10,
        "spec": Spec(
            _dense_spec(IMG * IMG * 3, 256, "fc1")
            + _dense_spec(256, 128, "fc2")
            + _dense_spec(128, 10, "fc3")
        ),
    },
    "cnn": {
        "task": "classify",
        "classes": 10,
        "spec": Spec(
            _conv_spec(3, 16, "c1")
            + _conv_spec(16, 32, "c2")
            + _conv_spec(32, 64, "c3")
            + _dense_spec(4 * 4 * 64, 128, "fc1")
            + _dense_spec(128, 10, "fc2")
        ),
    },
    "widecnn": {
        "task": "classify",
        "classes": 10,
        "spec": Spec(
            _conv_spec(3, 32, "c1")
            + _conv_spec(32, 64, "c2")
            + _conv_spec(64, 96, "c3")
            + _dense_spec(4 * 4 * 96, 768, "fc1")
            + _dense_spec(768, 256, "fc2")
            + _dense_spec(256, 10, "fc3")
        ),
    },
    "detector": {
        "task": "detect",
        "classes": 3,
        "spec": Spec(
            _conv_spec(3, 16, "c1")
            + _conv_spec(16, 32, "c2")
            + _conv_spec(32, 48, "c3")
            + _dense_spec(4 * 4 * 48, 128, "fc1")
            + _dense_spec(128, 3 + 4, "head")
        ),
    },
}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _conv_block(x, w, b):
    x = lax.conv_general_dilated(x, w, (1, 1), "SAME", dimension_numbers=DIMNUM)
    x = jax.nn.relu(x + b)
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _dense(x, w, b, *, pallas=False):
    y = pk_matmul.matmul(x, w) if pallas else jnp.dot(x, w)
    return y + b


def _forward(name: str, params: list, x, *, pallas_head: bool = False):
    """Shared forward over unflattened params. x: [B,32,32,3] f32 in [0,1]."""
    p = list(params)

    def pop2():
        w, b = p.pop(0), p.pop(0)
        return w, b

    if name == "mlp":
        h = x.reshape(x.shape[0], -1)
        w, b = pop2()
        h = jax.nn.relu(_dense(h, w, b))
        w, b = pop2()
        h = jax.nn.relu(_dense(h, w, b))
        w, b = pop2()
        return _dense(h, w, b, pallas=pallas_head)

    n_convs = {"cnn": 3, "widecnn": 3, "detector": 3}[name]
    h = x
    for _ in range(n_convs):
        w, b = pop2()
        h = _conv_block(h, w, b)
    h = h.reshape(h.shape[0], -1)
    while len(p) > 2:
        w, b = pop2()
        h = jax.nn.relu(_dense(h, w, b))
    w, b = pop2()
    out = _dense(h, w, b, pallas=pallas_head)
    if name == "detector":
        # logits[:, :3] class scores; box (cx,cy,w,h) squashed to (0,1)
        cls, box = out[:, :3], jax.nn.sigmoid(out[:, 3:])
        out = jnp.concatenate([cls, box], axis=1)
    return out


def fwd(name: str):
    """(x, flat) -> outputs, float-weights variant (rust hot path)."""
    spec = ARCHS[name]["spec"]

    def f(x, flat):
        return (_forward(name, spec.unflatten(flat), x),)

    return f


def qfwd(name: str, k: int = 16):
    """(x, qflat, scales, los, half) -> outputs; Pallas dequant inside."""
    spec = ARCHS[name]["spec"]

    def f(x, qflat, scales, los, half):
        params = []
        for i, ((_, shape), off) in enumerate(zip(spec.entries, spec.offsets)):
            n = int(np.prod(shape))
            seg = lax.dynamic_slice(qflat, (off,), (n,))
            w = pk_dequant.dequant(seg, scales[i], los[i], half[0])
            params.append(w.reshape(shape))
        return (_forward(name, params, x, pallas_head=True),)

    return f


# ---------------------------------------------------------------------------
# Init + loss
# ---------------------------------------------------------------------------

def init_params(name: str, seed: int) -> list[np.ndarray]:
    """He-normal init, numpy (so the artifact is reproducible)."""
    rng = np.random.default_rng(seed)
    out = []
    for pname, shape in ARCHS[name]["spec"].entries:
        if pname.endswith(".b"):
            out.append(np.zeros(shape, dtype=np.float32))
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = np.sqrt(2.0 / fan_in)
            out.append(rng.normal(0.0, std, size=shape).astype(np.float32))
    return out


def loss_fn(name: str):
    """Returns loss(flat, x, y[, boxes]) for training."""
    spec = ARCHS[name]["spec"]
    task = ARCHS[name]["task"]

    def ce(logits, y):
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    if task == "classify":

        def f(flat, x, y):
            (logits,) = fwd(name)(x, flat)
            return ce(logits, y)

        return f

    def f(flat, x, y, boxes):
        (out,) = fwd(name)(x, flat)
        cls, box = out[:, :3], out[:, 3:]
        return ce(cls, y) + 5.0 * jnp.mean(jnp.abs(box - boxes))

    return f
