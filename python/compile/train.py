"""Build-time training for the substitute model zoo (hand-rolled Adam).

optax is unavailable offline, so Adam is implemented directly over the
flat parameter vector. Training is deliberately small — each model reaches
high accuracy on its synthetic task in a few hundred steps on one CPU core.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, model


def adam_step(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    def step(i, flat, m, v, g):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (i + 1))
        vh = v / (1 - b2 ** (i + 1))
        return flat - lr * mh / (jnp.sqrt(vh) + eps), m, v

    return step


def _batches(n, batch, steps, seed):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        yield rng.integers(0, n, size=batch)


def train_classifier(name: str, steps: int, batch: int = 64, n_train: int = 4096,
                     lr: float = 1e-3, seed: int = 7, log=print) -> np.ndarray:
    """Train a shapes10 classifier; returns the flat f32 parameter vector."""
    spec = model.ARCHS[name]["spec"]
    x_all, y_all = datasets.shapes10(n_train, datasets.TRAIN_SEED_SHAPES)
    flat = jnp.asarray(spec.flatten_np(model.init_params(name, seed)))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    loss = model.loss_fn(name)
    step = adam_step(lr)

    @jax.jit
    def update(i, flat, m, v, x, y):
        l, g = jax.value_and_grad(loss)(flat, x, y)
        flat, m, v = step(i, flat, m, v, g)
        return flat, m, v, l

    t0 = time.time()
    for i, idx in enumerate(_batches(n_train, batch, steps, seed + 1)):
        flat, m, v, l = update(i, flat, m, v, jnp.asarray(x_all[idx]), jnp.asarray(y_all[idx]))
        if i % 100 == 0 or i == steps - 1:
            log(f"  [{name}] step {i:4d} loss {float(l):.4f} ({time.time()-t0:.0f}s)")
    return np.asarray(flat)


def train_detector(name: str, steps: int, batch: int = 64, n_train: int = 4096,
                   lr: float = 1e-3, seed: int = 11, log=print) -> np.ndarray:
    """Train the boxfind detector; returns the flat f32 parameter vector."""
    spec = model.ARCHS[name]["spec"]
    x_all, y_all, b_all = datasets.boxfind(n_train, datasets.TRAIN_SEED_BOX)
    flat = jnp.asarray(spec.flatten_np(model.init_params(name, seed)))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    loss = model.loss_fn(name)
    step = adam_step(lr)

    @jax.jit
    def update(i, flat, m, v, x, y, bx):
        l, g = jax.value_and_grad(loss)(flat, x, y, bx)
        flat, m, v = step(i, flat, m, v, g)
        return flat, m, v, l

    t0 = time.time()
    for i, idx in enumerate(_batches(n_train, batch, steps, seed + 1)):
        flat, m, v, l = update(
            i, flat, m, v, jnp.asarray(x_all[idx]), jnp.asarray(y_all[idx]), jnp.asarray(b_all[idx])
        )
        if i % 100 == 0 or i == steps - 1:
            log(f"  [{name}] step {i:4d} loss {float(l):.4f} ({time.time()-t0:.0f}s)")
    return np.asarray(flat)


def eval_classifier(name: str, flat: np.ndarray, n: int = 512) -> float:
    x, y = datasets.shapes10(n, datasets.EVAL_SEED_SHAPES)
    (logits,) = jax.jit(model.fwd(name))(jnp.asarray(x), jnp.asarray(flat))
    return float(np.mean(np.argmax(np.asarray(logits), axis=1) == y))


def eval_detector(name: str, flat: np.ndarray, n: int = 512) -> tuple[float, float]:
    """Returns (class accuracy, mean IoU)."""
    x, y, b = datasets.boxfind(n, datasets.EVAL_SEED_BOX)
    (out,) = jax.jit(model.fwd(name))(jnp.asarray(x), jnp.asarray(flat))
    out = np.asarray(out)
    acc = float(np.mean(np.argmax(out[:, :3], axis=1) == y))
    iou = float(np.mean(_iou_cxcywh(out[:, 3:], b)))
    return acc, iou


def _iou_cxcywh(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    def corners(t):
        cx, cy, w, h = t[:, 0], t[:, 1], t[:, 2], t[:, 3]
        return cx - w / 2, cy - h / 2, cx + w / 2, cy + h / 2

    ax0, ay0, ax1, ay1 = corners(a)
    bx0, by0, bx1, by1 = corners(b)
    ix = np.maximum(0, np.minimum(ax1, bx1) - np.maximum(ax0, bx0))
    iy = np.maximum(0, np.minimum(ay1, by1) - np.maximum(ay0, by0))
    inter = ix * iy
    union = (ax1 - ax0) * (ay1 - ay0) + (bx1 - bx0) * (by1 - by0) - inter
    return inter / np.maximum(union, 1e-9)
