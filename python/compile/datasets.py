"""Synthetic datasets for the ProgressiveNet reproduction.

The paper evaluates on ImageNet / MS-COCO with pre-trained models; offline
we substitute procedurally generated datasets (see DESIGN.md §2):

- ``shapes10``: 32x32x3 RGB images, 10 pattern classes (classification —
  stands in for the ImageNet top-1 experiments of Table II rows 2-4).
- ``boxfind``: 32x32x3 RGB images containing a single colored object on a
  textured background; the task is to predict the object class (3 classes)
  and its bounding box (detection — stands in for the COCO boxAP
  experiments of Table II rows 5-7).

Everything is pure numpy and fully deterministic given a seed, so the same
eval split can be regenerated bit-exactly and is also dumped into
``artifacts/data/`` for the rust side.
"""

from __future__ import annotations

import numpy as np

IMG = 32
N_CLASSES_SHAPES = 10
N_CLASSES_BOX = 3


# ---------------------------------------------------------------------------
# shapes10
# ---------------------------------------------------------------------------

def _grid():
    y, x = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    return x, y


def _shapes10_image(rng: np.random.Generator, label: int) -> np.ndarray:
    """Render one 32x32x3 image of pattern class ``label`` (0..9)."""
    x, y = _grid()
    img = rng.normal(0.5, 0.08, size=(IMG, IMG, 3)).astype(np.float32)
    c = rng.uniform(0.55, 1.0, size=3).astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.5, 1.2)
    cx, cy = rng.uniform(10, 22, size=2)
    r = rng.uniform(5, 11)

    if label == 0:  # horizontal stripes
        mask = 0.5 + 0.5 * np.sin(freq * y + phase)
    elif label == 1:  # vertical stripes
        mask = 0.5 + 0.5 * np.sin(freq * x + phase)
    elif label == 2:  # diagonal stripes
        mask = 0.5 + 0.5 * np.sin(freq * (x + y) / np.sqrt(2) + phase)
    elif label == 3:  # filled circle
        mask = ((x - cx) ** 2 + (y - cy) ** 2 <= r * r).astype(np.float32)
    elif label == 4:  # ring
        d = np.sqrt((x - cx) ** 2 + (y - cy) ** 2)
        mask = (np.abs(d - r) <= 2.0).astype(np.float32)
    elif label == 5:  # filled square
        mask = ((np.abs(x - cx) <= r * 0.8) & (np.abs(y - cy) <= r * 0.8)).astype(np.float32)
    elif label == 6:  # cross
        mask = ((np.abs(x - cx) <= 2.0) | (np.abs(y - cy) <= 2.0)).astype(np.float32)
    elif label == 7:  # checkerboard
        s = max(2, int(rng.integers(3, 6)))
        mask = (((x // s) + (y // s)) % 2).astype(np.float32)
    elif label == 8:  # radial gradient
        d = np.sqrt((x - cx) ** 2 + (y - cy) ** 2)
        mask = np.clip(1.0 - d / (IMG * 0.75), 0, 1)
    else:  # label == 9: diagonal gradient
        mask = (x + y) / (2 * (IMG - 1))

    mask = mask.astype(np.float32)[..., None]
    img = img * (1 - 0.85 * mask) + 0.85 * mask * c[None, None, :]
    return np.clip(img, 0.0, 1.0)


def shapes10(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` (image, label) pairs. Returns (x [n,32,32,3] f32, y [n] i32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES_SHAPES, size=n).astype(np.int32)
    imgs = np.stack([_shapes10_image(rng, int(l)) for l in labels])
    return imgs.astype(np.float32), labels


# ---------------------------------------------------------------------------
# boxfind
# ---------------------------------------------------------------------------

def _boxfind_image(rng: np.random.Generator, label: int):
    """One image with a single object of class ``label``; returns (img, box).

    Box is (cx, cy, w, h), all normalized to [0, 1].
    """
    x, y = _grid()
    img = rng.normal(0.45, 0.1, size=(IMG, IMG, 3)).astype(np.float32)
    # background texture
    img += 0.08 * np.sin(0.7 * x + rng.uniform(0, 6))[..., None]

    w = rng.uniform(7, 16)
    h = rng.uniform(7, 16)
    cx = rng.uniform(w / 2 + 1, IMG - w / 2 - 1)
    cy = rng.uniform(h / 2 + 1, IMG - h / 2 - 1)
    color = np.zeros(3, dtype=np.float32)
    color[label] = 1.0
    color += rng.uniform(-0.08, 0.08, size=3).astype(np.float32)

    if label == 0:  # red rectangle
        mask = ((np.abs(x - cx) <= w / 2) & (np.abs(y - cy) <= h / 2)).astype(np.float32)
    elif label == 1:  # green ellipse
        mask = ((((x - cx) / (w / 2)) ** 2 + ((y - cy) / (h / 2)) ** 2) <= 1.0).astype(np.float32)
    else:  # blue diamond
        mask = ((np.abs(x - cx) / (w / 2) + np.abs(y - cy) / (h / 2)) <= 1.0).astype(np.float32)

    mask = mask[..., None]
    img = img * (1 - 0.9 * mask) + 0.9 * mask * color[None, None, :]
    box = np.array([cx / IMG, cy / IMG, w / IMG, h / IMG], dtype=np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32), box


def boxfind(n: int, seed: int):
    """Generate ``n`` detection samples.

    Returns (x [n,32,32,3] f32, labels [n] i32, boxes [n,4] f32 cxcywh-normalized).
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES_BOX, size=n).astype(np.int32)
    imgs, boxes = [], []
    for l in labels:
        im, b = _boxfind_image(rng, int(l))
        imgs.append(im)
        boxes.append(b)
    return np.stack(imgs), labels, np.stack(boxes)


# Canonical eval splits (dumped into artifacts/, also used by pytest).
EVAL_SEED_SHAPES = 90210
EVAL_SEED_BOX = 31337
TRAIN_SEED_SHAPES = 1234
TRAIN_SEED_BOX = 5678
EVAL_N = 256
