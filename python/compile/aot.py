"""AOT compile path: train models, lower to HLO text, dump artifacts.

Run once via ``make artifacts``; afterwards the rust binary is fully
self-contained. Emits, per model:

    artifacts/models/<name>/
        weights.bin      flat f32 LE parameter vector (manifest order)
        manifest.json    tensors (name/shape/numel/offset/min/max), task,
                         accuracy, hlo file index, codec parameters
        fwd_b{B}.hlo.txt   (x[B,...], flat f32[P]) -> outputs
        qfwd_b{B}.hlo.txt  (x, qflat u32[P], scales[T], los[T], half[1])
                           -> outputs, Pallas dequant + Pallas matmul head

plus eval datasets under artifacts/data/<ds>/ and cross-language golden
vectors under artifacts/golden/ (the rust codec is tested against these).

HLO **text** is the interchange format — xla_extension 0.5.1 rejects
jax>=0.5 serialized HloModuleProto (64-bit instruction ids); the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model, train
from .kernels import ref

FWD_BATCHES = [1, 32, 256]
QFWD_BATCHES = [1, 32, 256]
DEFAULT_SCHEDULE = [2, 2, 2, 2, 2, 2, 2, 2]

TRAIN_CFG = {
    # name: (kind, steps, lr)
    "mlp": ("classify", 500, 1e-3),
    "cnn": ("classify", 600, 1.5e-3),
    "widecnn": ("classify", 450, 1e-3),
    "detector": ("detect", 600, 1.5e-3),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def pack_plane_np(values: np.ndarray, width: int) -> bytes:
    """Tight MSB-first bit-packing of a u32 plane with ``width`` bits/elem.

    Contract shared with rust/src/quant/bitplane.rs.
    """
    out = bytearray()
    acc = 0
    nbits = 0
    mask = (1 << width) - 1
    for v in values:
        acc = (acc << width) | (int(v) & mask)
        nbits += width
        while nbits >= 8:
            nbits -= 8
            out.append((acc >> nbits) & 0xFF)
    if nbits:
        out.append((acc << (8 - nbits)) & 0xFF)
    return bytes(out)


# ---------------------------------------------------------------------------


def emit_model(name: str, flat: np.ndarray, out_dir: str, acc: dict, log=print):
    spec = model.ARCHS[name]["spec"]
    task = model.ARCHS[name]["task"]
    os.makedirs(out_dir, exist_ok=True)

    # weights
    flat = flat.astype("<f4")
    flat.tofile(os.path.join(out_dir, "weights.bin"))

    # tensor manifest with quantization params
    tensors = spec.manifest()
    for t in tensors:
        seg = flat[t["offset"] : t["offset"] + t["numel"]]
        lo, hi = ref.qparams(seg)
        t["min"], t["max"] = lo, hi

    in_shape = [datasets.IMG, datasets.IMG, 3]
    hlo_index = {}

    for b in FWD_BATCHES:
        x_spec = jax.ShapeDtypeStruct((b, *in_shape), jnp.float32)
        f_spec = jax.ShapeDtypeStruct((spec.total,), jnp.float32)
        t0 = time.time()
        lowered = jax.jit(model.fwd(name)).lower(x_spec, f_spec)
        text = to_hlo_text(lowered)
        fn = f"fwd_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fn), "w") as f:
            f.write(text)
        hlo_index[f"fwd_b{b}"] = fn
        log(f"  [{name}] {fn}: {len(text)//1024} KiB ({time.time()-t0:.1f}s)")

    ntens = len(tensors)
    for b in QFWD_BATCHES:
        x_spec = jax.ShapeDtypeStruct((b, *in_shape), jnp.float32)
        q_spec = jax.ShapeDtypeStruct((spec.total,), jnp.uint32)
        s_spec = jax.ShapeDtypeStruct((ntens,), jnp.float32)
        h_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
        t0 = time.time()
        lowered = jax.jit(model.qfwd(name)).lower(x_spec, q_spec, s_spec, s_spec, h_spec)
        text = to_hlo_text(lowered)
        fn = f"qfwd_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fn), "w") as f:
            f.write(text)
        hlo_index[f"qfwd_b{b}"] = fn
        log(f"  [{name}] {fn}: {len(text)//1024} KiB ({time.time()-t0:.1f}s)")

    manifest = {
        "name": name,
        "task": task,
        "classes": model.ARCHS[name]["classes"],
        "input_shape": in_shape,
        "param_count": int(spec.total),
        "k": ref.K,
        "default_schedule": DEFAULT_SCHEDULE,
        "tensors": tensors,
        "hlo": hlo_index,
        "weights": "weights.bin",
        "accuracy": acc,
        "dataset": "shapes10" if task == "classify" else "boxfind",
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def emit_data(root: str, log=print):
    dd = os.path.join(root, "data")
    # shapes10
    d = os.path.join(dd, "shapes10")
    os.makedirs(d, exist_ok=True)
    x, y = datasets.shapes10(datasets.EVAL_N, datasets.EVAL_SEED_SHAPES)
    x.astype("<f4").tofile(os.path.join(d, "images.bin"))
    y.astype("<i4").tofile(os.path.join(d, "labels.bin"))
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(
            {
                "name": "shapes10",
                "n": int(datasets.EVAL_N),
                "image_shape": [32, 32, 3],
                "classes": [
                    "h-stripes", "v-stripes", "d-stripes", "circle", "ring",
                    "square", "cross", "checker", "radial", "gradient",
                ],
                "files": {"images": "images.bin", "labels": "labels.bin"},
            },
            f, indent=1,
        )
    log(f"  [data] shapes10 eval: {datasets.EVAL_N} images")
    # boxfind
    d = os.path.join(dd, "boxfind")
    os.makedirs(d, exist_ok=True)
    x, y, b = datasets.boxfind(datasets.EVAL_N, datasets.EVAL_SEED_BOX)
    x.astype("<f4").tofile(os.path.join(d, "images.bin"))
    y.astype("<i4").tofile(os.path.join(d, "labels.bin"))
    b.astype("<f4").tofile(os.path.join(d, "boxes.bin"))
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(
            {
                "name": "boxfind",
                "n": int(datasets.EVAL_N),
                "image_shape": [32, 32, 3],
                "classes": ["red-box", "green-ellipse", "blue-diamond"],
                "files": {"images": "images.bin", "labels": "labels.bin", "boxes": "boxes.bin"},
            },
            f, indent=1,
        )
    log(f"  [data] boxfind eval: {datasets.EVAL_N} images")


def emit_golden(root: str, log=print):
    """Cross-language golden vectors for the rust codec tests."""
    gd = os.path.join(root, "golden")
    os.makedirs(gd, exist_ok=True)
    rng = np.random.default_rng(424242)
    m = (rng.normal(0, 0.25, size=5000) * rng.uniform(0.2, 1.5)).astype(np.float32)
    lo, hi = ref.qparams(m)
    q = ref.quantize_np(m)
    widths = DEFAULT_SCHEDULE
    parts = ref.split_np(q, widths)
    packed = [pack_plane_np(p, w) for p, w in zip(parts, widths)]
    stages = []
    cum = 0
    for i, w in enumerate(widths):
        cum += w
        qc = ref.concat_np(parts[: i + 1], widths[: i + 1])
        deq = ref.dequantize_np(qc, lo, hi, cum)
        stages.append(
            {
                "cum_bits": cum,
                "plane_crc32": zlib.crc32(packed[i]) & 0xFFFFFFFF,
                "plane_len": len(packed[i]),
                "q_head": [int(v) for v in qc[:32]],
                "deq_head": [float(v) for v in deq[:32]],
                "deq_max_abs_err": float(np.max(np.abs(deq - m))),
            }
        )
    m.astype("<f4").tofile(os.path.join(gd, "weights.bin"))
    q.astype("<u4").tofile(os.path.join(gd, "q16.bin"))
    for i, p in enumerate(packed):
        with open(os.path.join(gd, f"plane{i}.bin"), "wb") as f:
            f.write(p)
    with open(os.path.join(gd, "codec.json"), "w") as f:
        json.dump(
            {
                "n": int(m.size), "k": ref.K, "min": lo, "max": hi,
                "widths": widths, "stages": stages,
                "q_crc32": zlib.crc32(q.astype("<u4").tobytes()) & 0xFFFFFFFF,
            },
            f, indent=1,
        )
    log(f"  [golden] codec vectors: n={m.size}")


def emit_kernel_smoke(root: str, log=print):
    """Tiny HLO combining the Pallas dequant + matmul kernels, for the
    rust runtime integration test (independent of trained models)."""
    from .kernels import dequant as pk_dequant
    from .kernels import matmul as pk_matmul

    def f(q, scale, lo, half, x):
        w = pk_dequant.dequant(q, scale, lo, half).reshape(64, 32)
        return (pk_matmul.matmul(x, w),)

    q_spec = jax.ShapeDtypeStruct((2048,), jnp.uint32)
    s_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    lowered = jax.jit(f).lower(q_spec, s_spec, s_spec, s_spec, x_spec)
    text = to_hlo_text(lowered)
    with open(os.path.join(root, "kernel_smoke.hlo.txt"), "w") as fh:
        fh.write(text)
    log(f"  [smoke] kernel_smoke.hlo.txt: {len(text)//1024} KiB")


def train_model(name: str, log=print) -> tuple[np.ndarray, dict]:
    kind, steps, lr = TRAIN_CFG[name]
    t0 = time.time()
    if kind == "classify":
        flat = train.train_classifier(name, steps=steps, lr=lr, log=log)
        top1 = train.eval_classifier(name, flat)
        acc = {"top1": top1}
        log(f"  [{name}] trained: top1={top1:.3f} ({time.time()-t0:.0f}s)")
    else:
        flat = train.train_detector(name, steps=steps, lr=lr, log=log)
        cls_acc, iou = train.eval_detector(name, flat)
        acc = {"cls_acc": cls_acc, "mean_iou": iou}
        log(f"  [{name}] trained: cls={cls_acc:.3f} iou={iou:.3f} ({time.time()-t0:.0f}s)")
    return flat, acc


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--models", default=",".join(TRAIN_CFG), help="comma list")
    ap.add_argument("--retrain", action="store_true", help="ignore cached weights")
    args = ap.parse_args()
    root = os.path.abspath(args.out)
    os.makedirs(root, exist_ok=True)
    names = [n for n in args.models.split(",") if n]

    emit_data(root, log=print)
    emit_golden(root, log=print)
    emit_kernel_smoke(root, log=print)

    index = []
    for name in names:
        out_dir = os.path.join(root, "models", name)
        wpath = os.path.join(out_dir, "weights.bin")
        mpath = os.path.join(out_dir, "manifest.json")
        if not args.retrain and os.path.exists(wpath) and os.path.exists(mpath):
            with open(mpath) as f:
                acc = json.load(f)["accuracy"]
            flat = np.fromfile(wpath, dtype="<f4")
            print(f"  [{name}] using cached weights ({flat.size} params)")
        else:
            flat, acc = train_model(name, log=print)
        manifest = emit_model(name, flat, out_dir, acc, log=print)
        index.append({"name": name, "task": manifest["task"], "params": manifest["param_count"]})

    with open(os.path.join(root, "models", "index.json"), "w") as f:
        json.dump({"models": index}, f, indent=1)
    print(f"artifacts complete at {root}")


if __name__ == "__main__":
    main()
