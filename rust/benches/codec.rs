//! Codec micro-benchmarks: throughput of each hot-path primitive
//! (quantize, bit-plane pack/unpack, incremental concat, dequantize).
//!
//! These are the L3 §Perf numbers tracked in EXPERIMENTS.md. Method:
//! best-of-5 timed repetitions over a 4M-element tensor (16 MB f32),
//! reporting elements/s and effective GB/s of input consumed.

use std::time::Instant;

use prognet::metrics::Table;
use prognet::quant::{
    bitplane, dequantize_into, quantize, Accumulator, DequantParams, QuantParams, Schedule, K,
};
use prognet::util::rng::Rng;

const N: usize = 4_000_000;
const REPS: usize = 5;

fn best_of<F: FnMut()>(mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let mut rng = Rng::new(7);
    let data: Vec<f32> = (0..N).map(|_| rng.normal_ms(0.0, 0.4) as f32).collect();
    let qp = QuantParams::from_data(&data, K);
    let sched = Schedule::paper_default();

    let mut table = Table::new(
        &format!("codec micro-bench ({} M elements, best of {REPS})", N / 1_000_000),
        &["primitive", "time", "Melem/s", "GB/s (in)"],
    );
    let mut row = |name: &str, secs: f64, in_bytes: usize| {
        table.row(vec![
            name.to_string(),
            format!("{:.1} ms", secs * 1e3),
            format!("{:.0}", N as f64 / secs / 1e6),
            format!("{:.2}", in_bytes as f64 / secs / 1e9),
        ]);
    };

    // quantize (Eq. 2)
    let mut q = vec![0u32; N];
    let t = best_of(|| quantize::quantize_into(&data, &qp, &mut q));
    row("quantize (Eq.2)", t, N * 4);

    // split+pack one 2-bit plane (Eq. 3)
    let t = best_of(|| {
        let plane = bitplane::split_plane(&q, &sched, 0);
        let _ = bitplane::pack_plane(&plane, 2);
    });
    row("split+pack 2-bit plane (Eq.3)", t, N * 4);

    // unpack + OR-concat one plane (Eq. 4, client hot path); the real
    // client reuses its accumulator, so allocation is outside the timing
    let packed = bitplane::pack_plane(&bitplane::split_plane(&q, &sched, 0), 2);
    let mut acc = Accumulator::new(N, sched.clone());
    let t = best_of(|| {
        acc.reset();
        acc.absorb(&packed).unwrap();
    });
    row("unpack+concat 2-bit plane (Eq.4)", t, packed.len());

    // dequantize (Eq. 5, per-stage hot path)
    let mut out = vec![0f32; N];
    let dp = DequantParams::new(&qp, K);
    let t = best_of(|| dequantize_into(&q, dp, &mut out));
    row("dequantize (Eq.5)", t, N * 4);

    // full stage: unpack + concat + dequant (what the client does per stage)
    let t = best_of(|| {
        acc.reset();
        acc.absorb(&packed).unwrap();
        dequantize_into(acc.codes(), DequantParams::new(&qp, 2), &mut out);
    });
    row("full stage reconstruct", t, packed.len() + N * 4);

    // full encode (server, once per deployment)
    let t = best_of(|| {
        let q2 = quantize::quantize(&data, &qp);
        let _ = bitplane::encode_planes(&q2, &sched);
    });
    row("full encode (8 stages)", t, N * 4);

    println!("{}", table.render());
    println!("§Perf target (DESIGN.md): ≥1 GB/s/core for the per-stage reconstruct path.");
}
