//! §III-A ablation — the naive decimal digit-split (Eq. 1) vs the
//! quantization bit-split codec (Eqs. 2–5).
//!
//! The paper rejects digit splitting as "not efficient in terms of
//! representation space"; this bench quantifies that on real trained
//! weights: bytes on the wire per stage vs reconstruction error.

use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::quant::{bitplane, naive, quantize, Accumulator, DequantParams, QuantParams, Schedule, K};
use prognet::util::stats::fmt_bytes;

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        eprintln!("ablation_naive_split: artifacts not built, skipping");
        return Ok(());
    }
    let registry = Registry::open_default()?;
    let m = registry.get("cnn")?;
    let flat = m.load_weights()?;

    // ---- bit-split (4 stages of 4 bits, to match 4 digit groups)
    let sched = Schedule::new(vec![4; 4], K)?;
    let qp = QuantParams::from_data(&flat, K);
    let q = quantize::quantize(&flat, &qp);
    let planes = bitplane::encode_planes(&q, &sched);
    let mut acc = Accumulator::new(flat.len(), sched.clone());
    let mut out = vec![0f32; flat.len()];

    // ---- naive digit-split (8 significand digits in 4 stages)
    let enc = naive::encode(&flat, 4)?;

    let mut table = Table::new(
        "Eq. 1 ablation — naive digit-split vs quantization bit-split (cnn weights)",
        &[
            "stage",
            "bit-split bytes (cum)",
            "bit-split max err",
            "naive bytes (cum)",
            "naive max err",
            "size ratio",
        ],
    );
    let mut bs_bytes = 0usize;
    let mut nv_bytes = 0usize;
    for s in 0..4 {
        bs_bytes += planes[s].len();
        nv_bytes += enc.stage_bytes(s);
        acc.absorb(&planes[s])?;
        prognet::quant::dequantize_into(
            acc.codes(),
            DequantParams::new(&qp, sched.cum_bits(s)),
            &mut out,
        );
        let bs_err = flat
            .iter()
            .zip(&out)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        let nv = enc.decode(s + 1);
        let nv_err = flat
            .iter()
            .zip(&nv)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        table.row(vec![
            format!("{}", s + 1),
            fmt_bytes(bs_bytes as u64),
            format!("{bs_err:.2e}"),
            fmt_bytes(nv_bytes as u64),
            format!("{nv_err:.2e}"),
            format!("{:.2}x", nv_bytes as f64 / bs_bytes as f64),
        ]);
    }
    println!("{}", table.render());
    let ratio = enc.total_bytes() as f64 / bs_bytes as f64;
    println!(
        "naive total {} vs bit-split total {} — {:.2}x larger for comparable\n\
         final precision; the paper's reason to use quantization (§III-A/B).",
        fmt_bytes(enc.total_bytes() as u64),
        fmt_bytes(bs_bytes as u64),
        ratio
    );
    assert!(ratio > 1.5, "naive must cost substantially more wire bytes");
    Ok(())
}
