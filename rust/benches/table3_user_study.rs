//! Table III + Fig 8 — the (simulated) user study.
//!
//! Protocol identical to the paper: 6 labeling stages, 8–12 images each,
//! groups A (singleton) and B (progressive), link speeds 0.1 / 0.2 /
//! 0.5 MB/s, MobileNetV2-sized transfer (7.1 MB). Participants are the
//! behavioural model of `sim::user` (DESIGN.md §2 documents why and how
//! it is calibrated). Expected shape: B > A at every speed; paper overall
//! A=45%, B=71%.

use prognet::metrics::Table;
use prognet::sim::study::{run_table3, StudyConfig};
use prognet::sim::survey::survey_from_waits;

fn main() {
    // n=29/28 in the paper; use a larger synthetic cohort for stability,
    // plus the paper-sized cohort for the literal table.
    for (label, users) in [("paper-sized cohort (n=29/group)", 29), ("large cohort (n=500/group)", 500)] {
        let cfg = StudyConfig {
            users_per_group: users,
            ..Default::default()
        };
        let rows = run_table3(&cfg);
        let mut t = Table::new(
            &format!("Table III — active users of 'Find automatically', {label}"),
            &["Network Speed", "images/stage", "Group A", "Group B"],
        );
        let (mut aa, mut na, mut ab, mut nb) = (0usize, 0usize, 0usize, 0usize);
        let mut waits_a = Vec::new();
        let mut waits_b = Vec::new();
        for (speed, images, a, b) in &rows {
            t.row(vec![
                format!("{speed} MB/s"),
                images.to_string(),
                format!("{:.0}%", a.active_ratio() * 100.0),
                format!("{:.0}%", b.active_ratio() * 100.0),
            ]);
            // With the paper-sized cohort (n=29) the per-cell estimate is
            // noisy (±9pp at 95%); only the large cohort must strictly
            // reproduce the B > A ordering per cell.
            if users > 100 {
                assert!(
                    b.active_ratio() > a.active_ratio(),
                    "paper shape violated at {speed} MB/s"
                );
            }
            aa += a.active;
            na += a.n;
            ab += b.active;
            nb += b.n;
            waits_a.extend_from_slice(&a.user_mean_waits);
            waits_b.extend_from_slice(&b.user_mean_waits);
        }
        t.row(vec![
            "Overall".into(),
            "-".into(),
            format!("{:.0}%", aa as f64 / na as f64 * 100.0),
            format!("{:.0}%", ab as f64 / nb as f64 * 100.0),
        ]);
        println!("{}", t.render());

        if users > 100 {
            let sa = survey_from_waits(&waits_a, 0.68, cfg.seed);
            let sb = survey_from_waits(&waits_b, 0.68, cfg.seed + 1);
            println!("{}", sa.render("Fig 8 — Group A (w/o progressive)"));
            println!("{}", sb.render("Fig 8 — Group B (w/ progressive)"));
            assert!(
                sb.mean_score() > sa.mean_score(),
                "Fig 8 shape: B must be more satisfied"
            );
            println!(
                "mean Likert score: A {:.2}, B {:.2} (higher = more satisfied)\n",
                sa.mean_score(),
                sb.mean_score()
            );
        }
    }
    println!("paper (Table III): A 44/42/50% overall 45%; B 67/64/88% overall 71%.");
}
