//! Time-to-first-inference: layer-granular streaming vs the
//! stage-granular baseline, on the netsim virtual clock, emitting
//! `BENCH_stream.json` so the latency trajectory is tracked across PRs.
//!
//! For each bandwidth trace the harness replays the same annotated
//! container and reports:
//!
//! - `ttfi_stream_s`  — pipelined executor: first dispatch the moment
//!   layer 0's stage-0 bits are down ([`run_pipelined`]);
//! - `ttfi_stage_s`   — baseline: inference waits for stage 0 to
//!   complete across all tensors;
//! - `layer0_pure_s`  — pure transmission of preamble + layer 0's
//!   stage-0 frames (the physical lower bound).
//!
//! Being virtual-time, the numbers are exact and machine-independent —
//! the assert is a protocol property, not a perf lottery. Env:
//!
//!   PROGNET_BENCH_NO_ASSERT  skip the pipelined-beats-baseline assert

use prognet::netsim::BandwidthTrace;
use prognet::runtime::{Backend, ReferenceBackend};
use prognet::testutil::stream::{annotated_writer, run_pipelined, stream_fixture};
use prognet::util::json::{self, Json};

fn main() -> prognet::Result<()> {
    let reg = stream_fixture("bench-stream-ttfi")?;
    let m = reg.get("stream3")?;
    let (w, _) = annotated_writer(m)?;
    let compiled = ReferenceBackend::with_threads(1).compile(m, &[])?;
    let n = 4;
    let images: Vec<f32> = (0..n * m.input_numel()).map(|i| (i % 13) as f32 * 0.07).collect();

    // three trace shapes (dur_s:rate_MBps): a paper-style slow mobile
    // link, a ramp-up from near-stall, and a bursty loop
    let traces = [
        ("slow-flat-0.1MBps", "4:0.1"),
        ("rampup-0.05-to-1", "1:0.05,1:0.25,2:1.0"),
        ("bursty-loop", "0.4:0.08,0.2:0.9"),
    ];

    let wire = w.to_bytes().len();
    println!(
        "stream_ttfi: '{}' {} params, {} B wire, {} layers\n",
        w.manifest().model,
        w.manifest().param_count(),
        wire,
        w.manifest().stage_index().layers()
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut all_ahead = true;
    for (name, spec) in traces {
        let trace = BandwidthTrace::parse(spec)?;
        let run = run_pipelined(&w, &trace, compiled.as_ref(), &images, n, 0)?;
        let speedup = run.ttfi_stage / run.ttfi_pipelined;
        all_ahead &= run.ttfi_pipelined < run.ttfi_stage;
        println!(
            "{name:>20}: stream {:.3} s  stage {:.3} s  layer0-pure {:.3} s  ({speedup:.2}x earlier)",
            run.ttfi_pipelined, run.ttfi_stage, run.layer0_pure
        );
        rows.push(json::obj(vec![
            ("trace", json::s(name)),
            ("spec", json::s(spec)),
            ("ttfi_stream_s", json::num(run.ttfi_pipelined)),
            ("ttfi_stage_s", json::num(run.ttfi_stage)),
            ("layer0_pure_s", json::num(run.layer0_pure)),
            ("speedup", json::num(speedup)),
            ("total_transfer_s", json::num(run.schedule.total_done)),
        ]));
    }

    let report = json::obj(vec![
        ("model", json::s("stream3")),
        ("params", json::num(w.manifest().param_count() as f64)),
        ("wire_bytes", json::num(wire as f64)),
        ("layers", json::num(w.manifest().stage_index().layers() as f64)),
        ("traces", json::arr(rows)),
    ]);
    std::fs::write("BENCH_stream.json", report.to_string())?;
    println!("\nwrote BENCH_stream.json");

    if std::env::var_os("PROGNET_BENCH_NO_ASSERT").is_none() {
        assert!(
            all_ahead,
            "pipelined TTFI failed to beat the stage baseline on some trace"
        );
    }
    Ok(())
}
