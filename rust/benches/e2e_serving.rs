//! End-to-end serving bench: the coordinator (router + dynamic batcher)
//! under closed-loop multi-threaded load, in three scenarios:
//!
//!  0. accept-path latency — connect → status frame on the streaming
//!     server (guards the blocking-accept change: no sleep-poll interval
//!     in front of every connection);
//!  1. steady state — fully downloaded model, throughput/latency;
//!  2. progressive refinement — weights hot-swap mid-load (the serve_e2e
//!     example's scenario), verifying serving never stalls.

use std::io::Read;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use prognet::coordinator::{BatcherConfig, Router};
use prognet::eval::EvalSet;
use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::runtime::Engine;
use prognet::server::service::open_fetch;
use prognet::server::FetchRequest;
use prognet::testutil::fixture::synthetic_server;
use prognet::util::stats::{fmt_secs, Summary};

const MODEL: &str = "mlp";

/// Accept-path latency probe: runs on synthetic models so it needs no
/// artifacts. The old accept loop sleep-polled every 2 ms on WouldBlock,
/// adding up to 2 ms before every connect was even seen; the blocking
/// listener must keep the connect → status round-trip well under that.
fn bench_accept_latency() -> prognet::Result<()> {
    let (server, _repo) = synthetic_server("bench-accept")?;
    // warm the encoding so probes measure the transport, not the encoder
    let req = FetchRequest::new("alpha").with_stages(0, 1);
    let (mut s, resp) = open_fetch(&server.addr(), &req)?;
    let mut body = vec![0u8; resp.remaining as usize];
    s.read_exact(&mut body)?;

    let mut lat = Summary::new();
    for _ in 0..200 {
        let t0 = Instant::now();
        let (mut s, resp) = open_fetch(&server.addr(), &req)?;
        let mut body = vec![0u8; resp.remaining as usize];
        s.read_exact(&mut body)?;
        lat.add(t0.elapsed().as_secs_f64());
    }
    println!(
        "accept path (connect → status → stage-0 body): p50={} p99={}",
        fmt_secs(lat.median()),
        fmt_secs(lat.p99())
    );
    // Escape hatch for loaded/virtualized hosts where 2 ms of scheduler
    // noise says nothing about the accept path itself.
    if std::env::var_os("PROGNET_BENCH_NO_ASSERT").is_none() {
        assert!(
            lat.median() < 0.002,
            "accept-path latency regressed: p50 {:.4}s is back in sleep-poll territory \
             (set PROGNET_BENCH_NO_ASSERT=1 to skip on noisy hosts)",
            lat.median()
        );
    }
    Ok(())
}

fn main() -> prognet::Result<()> {
    bench_accept_latency()?;
    if !prognet::artifacts_available() {
        eprintln!("e2e_serving: artifacts not built, skipping coordinator scenarios");
        return Ok(());
    }
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get(MODEL)?.clone();
    let eval = Arc::new(EvalSet::load_named(&manifest.dataset)?);
    let flat = Arc::new(manifest.load_weights()?);

    let mut table = Table::new(
        "e2e serving (router + dynamic batcher, closed loop)",
        &["scenario", "threads", "requests", "req/s", "p50", "p99"],
    );

    for (scenario, threads, swap) in [
        ("steady state", 1usize, false),
        ("steady state", 4, false),
        ("steady state", 8, false),
        ("hot-swap refinement", 4, true),
    ] {
        let router = Arc::new(Router::new(
            engine.clone(),
            Registry::open_default()?,
            BatcherConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(2),
                queue_cap: 1024,
            },
        ));
        router.publish_weights(MODEL, &flat, if swap { 2 } else { 16 })?;

        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                let router = router.clone();
                let eval = eval.clone();
                let stop = stop.clone();
                let served = served.clone();
                std::thread::spawn(move || {
                    let mut lat = Summary::new();
                    let mut i = w;
                    while !stop.load(Ordering::Relaxed) {
                        let img = eval.image(i % eval.n).to_vec();
                        let r = router.infer(MODEL, img).unwrap();
                        lat.add(r.latency.as_secs_f64());
                        served.fetch_add(1, Ordering::Relaxed);
                        i += 1;
                    }
                    lat
                })
            })
            .collect();

        if swap {
            // publish 8 refinements over the run
            for bits in [4u32, 6, 8, 10, 12, 14, 16] {
                std::thread::sleep(Duration::from_millis(120));
                router.publish_weights(MODEL, &flat, bits)?;
            }
            std::thread::sleep(Duration::from_millis(150));
        } else {
            std::thread::sleep(Duration::from_millis(1000));
        }
        stop.store(true, Ordering::Relaxed);
        let mut lat = Summary::new();
        for h in handles {
            for s in h.join().unwrap().samples() {
                lat.add(*s);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let n = served.load(Ordering::Relaxed);
        table.row(vec![
            scenario.into(),
            threads.to_string(),
            n.to_string(),
            format!("{:.0}", n as f64 / secs),
            fmt_secs(lat.median()),
            fmt_secs(lat.p99()),
        ]);
    }
    println!("{}", table.render());
    println!("§Perf target: coordinator overhead (queueing vs raw execute) small;\nsee runtime bench for the raw executable latency.");
    Ok(())
}
