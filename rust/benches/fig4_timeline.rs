//! Fig 4 — execution timelines: singleton vs progressive transmission
//! with and without concurrent inference, from measured compute profiles.
//!
//! Legend: `=` transfer, `r` concat+dequant, `I` inference, `*` output.

use prognet::eval::{harness, EvalSet};
use prognet::models::Registry;
use prognet::netsim::LinkSpec;
use prognet::quant::Schedule;
use prognet::runtime::Engine;
use prognet::util::stats::fmt_secs;

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        eprintln!("fig4_timeline: artifacts not built, skipping");
        return Ok(());
    }
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get("cnn")?;
    let eval = EvalSet::load_named(&manifest.dataset)?;
    let sched = Schedule::paper_default();
    let link = LinkSpec::mbps(0.25);

    let row = harness::run_exec_time(&engine, manifest, &eval, 32, &sched, link)?;

    println!(
        "Fig 4 — '{}' at 0.25 MB/s ('=' transfer, 'r' reconstruct, 'I' infer, '*' output)\n",
        row.model
    );
    println!("progressive w/o concurrent (transfer pauses for compute) — total {}:",
        fmt_secs(row.progressive_serial));
    print!("{}", row.timeline_serial.render_ascii(96));
    println!();
    println!("progressive w/ concurrent (§III-C) — total {} (singleton {}):",
        fmt_secs(row.progressive_concurrent), fmt_secs(row.singleton));
    print!("{}", row.timeline_concurrent.render_ascii(96));
    println!();

    // Fig 4's claim, machine-checked:
    assert!(row.progressive_serial > row.progressive_concurrent);
    assert!(row.progressive_concurrent <= row.singleton * 1.25);
    println!(
        "concurrent total within {:+.1}% of singleton; serial {:+.0}% over singleton.",
        (row.progressive_concurrent / row.singleton - 1.0) * 100.0,
        (row.progressive_serial / row.singleton - 1.0) * 100.0
    );
    Ok(())
}
