//! Table I — total execution time of progressive vs singleton models.
//!
//! Paper setup: models of 7.1–51.2 MB at 1 MB/s on an M1/Chrome client.
//! Here: our trained models (0.3–2.8 MB quantized) over the deterministic
//! virtual link, with *measured* per-stage reconstruct+inference costs
//! from the selected runtime backend (`PROGNET_BACKEND`, default:
//! reference interpreter). The link speed is scaled **per model** so
//! that total compute is ~50% of transfer time — the regime of the
//! paper's Table I, where browser inference cost 20–80% of the transfer
//! (MobileNetV2: 13s vs 8s). EXPERIMENTS.md documents the scaling.
//! Expected shape (paper): w/o concurrent +20–80%, w/ concurrent +0–2%.

use prognet::eval::{harness, EvalSet};
use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::netsim::LinkSpec;
use prognet::quant::Schedule;
use prognet::runtime::Engine;
use prognet::util::stats::{fmt_bytes, fmt_delta_pct, fmt_secs};

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        eprintln!("table1_exec_time: artifacts not built, skipping");
        return Ok(());
    }
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let sched = Schedule::paper_default();
    let workload = 32; // images inferred at each stage

    let mut table = Table::new(
        &format!(
            "Table I — total execution time (32-image workload, {} backend; \
             link scaled per model, see col. 3)",
            engine.backend_name()
        ),
        &[
            "Model",
            "Size (wire)",
            "Link",
            "Singleton",
            "Prog. w/o concurrent",
            "Prog. w/ concurrent",
            "First output",
        ],
    );
    for name in ["mlp", "cnn", "widecnn", "detector"] {
        let manifest = registry.get(name)?;
        let eval = EvalSet::load_named(&manifest.dataset)?;
        // measure compute, then pick the link so compute ≈ 50% of transfer
        // (the paper's Table I regime).
        let session = prognet::runtime::ModelSession::load_batches(
            &engine,
            manifest,
            &[manifest.best_fwd_batch(workload)?],
        )?;
        let profile = harness::measure_compute(&session, manifest, &eval, workload, &sched)?;
        let flat = manifest.load_weights()?;
        let wire = manifest.pnet_manifest(&flat, sched.clone())?.wire_bytes() as f64;
        let target_transfer = profile.total_compute() / 0.5;
        let mbps = wire / target_transfer / (1024.0 * 1024.0);
        let link = LinkSpec::mbps(mbps);
        let row = harness::exec_time_row(manifest, &profile, &sched, link)?;
        table.row(vec![
            name.to_string(),
            fmt_bytes(row.wire_bytes),
            format!("{mbps:.2} MB/s"),
            fmt_secs(row.singleton),
            format!(
                "{} ({})",
                fmt_secs(row.progressive_serial),
                fmt_delta_pct(row.singleton, row.progressive_serial)
            ),
            format!(
                "{} ({})",
                fmt_secs(row.progressive_concurrent),
                fmt_delta_pct(row.singleton, row.progressive_concurrent)
            ),
            fmt_secs(row.first_output),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper (Table I): w/o concurrent +21%..+80%, w/ concurrent +0%..+2%;\n\
         first approximate output available at a fraction of the total time."
    );
    Ok(())
}
