//! §III-C ablation — where does concurrency stop being free?
//!
//! Sweeps the link speed and reports the overhead of progressive
//! transmission (vs singleton) with and without concurrent execution.
//! Concurrency hides compute while the per-stage transfer gap exceeds
//! reconstruct+infer cost; past the crossover, even the concurrent client
//! pays — this locates that crossover for a real model + real measured
//! compute profile.

use prognet::eval::{harness, EvalSet};
use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::netsim::LinkSpec;
use prognet::quant::Schedule;
use prognet::runtime::{Engine, ModelSession};

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        eprintln!("ablation_concurrency_sweep: artifacts not built, skipping");
        return Ok(());
    }
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let manifest = registry.get("cnn")?;
    let eval = EvalSet::load_named(&manifest.dataset)?;
    let sched = Schedule::paper_default();
    let session = ModelSession::load_batches(&engine, manifest, &[32])?;
    // measure once, reuse across the sweep (compute is link-independent)
    let profile = harness::measure_compute(&session, manifest, &eval, 32, &sched)?;

    let mut table = Table::new(
        "§III-C ablation — overhead vs singleton across link speeds (cnn, 32-image workload)",
        &[
            "link MB/s",
            "stage gap (s)",
            "infer+rec (s)",
            "w/o concurrent",
            "w/ concurrent",
        ],
    );
    let per_stage_cost = profile.reconstruct.iter().zip(&profile.infer).map(|(a, b)| a + b);
    let mean_cost: f64 =
        per_stage_cost.clone().sum::<f64>() / profile.reconstruct.len() as f64;
    let mut crossover: Option<f64> = None;
    for speed in [0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        let link = LinkSpec::mbps(speed);
        let row = harness::exec_time_row(manifest, &profile, &sched, link)?;
        let gap = row.wire_bytes as f64 / link.bytes_per_sec / sched.stages() as f64;
        let over_serial = (row.progressive_serial / row.singleton - 1.0) * 100.0;
        let over_conc = (row.progressive_concurrent / row.singleton - 1.0) * 100.0;
        if over_conc > 5.0 && crossover.is_none() {
            crossover = Some(speed);
        }
        table.row(vec![
            format!("{speed}"),
            format!("{gap:.3}"),
            format!("{mean_cost:.3}"),
            format!("{over_serial:+.0}%"),
            format!("{over_conc:+.0}%"),
        ]);
    }
    println!("{}", table.render());
    match crossover {
        Some(s) => println!(
            "crossover: concurrent overhead exceeds 5% from ~{s} MB/s, where the\n\
             per-stage transfer gap drops below the reconstruct+infer cost\n\
             ({mean_cost:.3}s) — the §III-C condition."
        ),
        None => println!(
            "no crossover within the sweep: inference is cheap enough that\n\
             concurrency stays free up to 16 MB/s."
        ),
    }
    Ok(())
}
