//! Table II — accuracy (%) of progressive vs singleton (orig.) models at
//! every cumulative bit-width 2→16.
//!
//! Paper rows: ImageNet top-1 for 3 classifiers, COCO boxAP for 3
//! detectors. Substitution (DESIGN.md §2): shapes10 top-1 for our 3
//! classifiers and boxfind boxAP for the detector. Expected shape: ~0 at
//! 2–4 bits, recovery from 6–8, no loss at 16 vs orig.

use prognet::eval::{harness, EvalSet};
use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::quant::Schedule;
use prognet::runtime::{Engine, ModelSession};

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        eprintln!("table2_accuracy: artifacts not built, skipping");
        return Ok(());
    }
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let sched = Schedule::paper_default();
    let n = 256;

    let mut header: Vec<String> = vec!["Model".into(), "Metric".into()];
    header.extend(sched.cum_all().iter().map(|c| format!("{c}")));
    header.push("orig.".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let title = format!(
        "Table II — accuracy (%) by cumulative bit-width ({} backend)",
        engine.backend_name()
    );
    let mut table = Table::new(&title, &header_refs);

    for name in ["mlp", "cnn", "widecnn", "detector"] {
        let manifest = registry.get(name)?;
        let eval = EvalSet::load_named(&manifest.dataset)?;
        let n = n.min(eval.n);
        let session =
            ModelSession::load_batches(&engine, manifest, &[manifest.best_fwd_batch(n)?])?;
        let (per_stage, orig) = harness::table2_row(&session, manifest, &eval, n, &sched)?;
        let metric = if manifest.task == "detect" { "boxAP" } else { "top-1" };
        let mut row = vec![name.to_string(), metric.to_string()];
        row.extend(per_stage.iter().map(|a| format!("{:.1}", a * 100.0)));
        row.push(format!("{:.1}", orig * 100.0));
        table.row(row);

        // Machine-checkable paper shape: degraded early, no final loss.
        // (Our substitute tasks are easier than ImageNet, so shallow
        // models degrade more gracefully at 2–4 bits than the paper's —
        // the curve shape, not the exact collapse point, is the claim.)
        assert!(
            per_stage[0] < orig - 0.05,
            "{name}: 2-bit accuracy not degraded ({} vs orig {orig})",
            per_stage[0]
        );
        assert!(
            (per_stage[7] - orig).abs() <= 0.03 + orig * 0.03,
            "{name}: 16-bit {} vs orig {} — paper claims no final loss",
            per_stage[7],
            orig
        );
        for w in per_stage.windows(2) {
            assert!(
                w[1] >= w[0] - 0.08,
                "{name}: accuracy dropped sharply between stages: {per_stage:?}"
            );
        }
    }
    println!("{}", table.render());
    println!(
        "paper (Table II): 0.0 at 2–4 bits, recovery from 6 bits, 16-bit\n\
         equals orig. — same shape above (n=256 eval split)."
    );
    Ok(())
}
