//! Runtime fast-path benchmark: batched blocked kernels vs the scalar
//! oracle interpreter, worker-pool scaling, and per-stage upgrade
//! latency (incremental delta-dequant vs a full re-dequant), emitting
//! `BENCH_runtime.json` so the perf trajectory is tracked across PRs.
//!
//! Runs entirely on synthetic fixture models (no artifacts needed — the
//! CI `runtime-smoke` job runs this and asserts speedup ≥ 1); when the
//! Python-built artifacts are present, the classic per-model latency
//! table for the real zoo is printed as well.
//!
//! Knobs:
//!   PROGNET_BENCH_BATCH      batch size (default 32)
//!   PROGNET_BENCH_NO_ASSERT  skip the speedup ≥ 1 assert

use std::sync::Arc;
use std::time::Instant;

use prognet::client::Assembler;
use prognet::format::PnetWriter;
use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::quant::{quantize, QuantParams, Schedule, K};
use prognet::runtime::{
    ApproxModel, Backend, CompiledModel, Engine, ModelSession, ReferenceBackend,
};
use prognet::testutil::fixture;
use prognet::util::json;

fn bench<F: FnMut() -> prognet::Result<()>>(mut f: F, reps: usize) -> prognet::Result<f64> {
    // warmup
    f()?;
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// A ~134k-param dense model, big enough that kernel throughput (not
/// plan overhead) dominates.
fn bench_registry() -> prognet::Result<Registry> {
    let root = fixture::fixture_root("bench-runtime");
    let _ = std::fs::remove_dir_all(&root);
    let models = root.join("models");
    std::fs::create_dir_all(&models)?;
    fixture::write_model(
        &models,
        "mlp256",
        &[
            ("fc1.w", &[256usize, 256][..]),
            ("fc1.b", &[256][..]),
            ("fc2.w", &[256, 256][..]),
            ("fc2.b", &[256][..]),
            ("head.w", &[256, 10][..]),
            ("head.b", &[10][..]),
        ],
        0xBE7C_0001,
    )?;
    fixture::write_index(&models, &["mlp256"])?;
    Registry::open(&root)
}

fn main() -> prognet::Result<()> {
    let batch = env_usize("PROGNET_BENCH_BATCH", 32);
    let reg = bench_registry()?;
    let manifest = reg.get("mlp256")?;
    let flat = manifest.load_weights()?;
    let images: Vec<f32> = (0..batch * manifest.input_numel())
        .map(|i| ((i * 2654435761) % 1000) as f32 * 1e-3)
        .collect();

    // ---- batched (1 worker) vs the pre-PR scalar interpreter ----------
    let scalar = ReferenceBackend::scalar().compile(manifest, &[])?;
    let batched = ReferenceBackend::with_threads(1).compile(manifest, &[])?;
    let t_scalar = bench(|| scalar.execute(&images, batch, &flat).map(|_| ()), 7)?;
    let t_batched = bench(|| batched.execute(&images, batch, &flat).map(|_| ()), 15)?;
    let speedup = t_scalar / t_batched;

    // ---- worker-pool scaling ------------------------------------------
    let threads = prognet::runtime::threads().min(8);
    let pooled = ReferenceBackend::with_threads(threads).compile(manifest, &[])?;
    let t_pooled = bench(|| pooled.execute(&images, batch, &flat).map(|_| ()), 15)?;

    let mut table = Table::new(
        &format!("runtime fast path (mlp256, {} params, batch {batch})", flat.len()),
        &["path", "latency", "images/s"],
    );
    for (name, t) in [
        ("scalar oracle (pre-PR)".to_string(), t_scalar),
        ("batched, 1 thread".to_string(), t_batched),
        (format!("batched, {threads} threads"), t_pooled),
    ] {
        table.row(vec![
            name,
            format!("{:.3} ms", t * 1e3),
            format!("{:.0}", batch as f64 / t),
        ]);
    }
    println!("{}", table.render());
    println!("speedup (batched/1-thread vs scalar at batch {batch}): {speedup:.2}x");

    // ---- per-stage upgrade latency: delta dequant vs full re-dequant --
    let sched = Schedule::paper_default();
    let pm = manifest.pnet_manifest(&flat, sched.clone())?;
    let writer = PnetWriter::encode(pm.clone(), &flat)?;
    let session = Arc::new(ModelSession::load(&Engine::reference(), manifest)?);
    let approx = ApproxModel::new(session);
    let tensors = pm.tensors.len();

    let mut delta = Assembler::new(pm.clone());
    delta.set_eager_dequant(true); // Eq. 5 folded into absorb
    let mut full = Assembler::new(pm.clone()); // lazy: reconstruct re-dequants
    let mut delta_us: Vec<f64> = Vec::new();
    let mut full_us: Vec<f64> = Vec::new();
    for s in 0..sched.stages() {
        for t in 0..tensors {
            delta.absorb(s, t, writer.fragment(s, t))?;
            full.absorb(s, t, writer.fragment(s, t))?;
        }
        // the StageComplete → ModelReady critical path: reconstruct + swap
        let t0 = Instant::now();
        delta.reconstruct()?;
        approx.publish(delta.flat(), delta.cum_bits());
        delta_us.push(t0.elapsed().as_secs_f64() * 1e6);
        let t0 = Instant::now();
        full.reconstruct()?;
        full_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "stage upgrade (reconstruct+swap, {} params): delta {:.1} us mean / {:.1} us max, \
         full re-dequant {:.1} us mean",
        flat.len(),
        mean(&delta_us),
        delta_us.iter().cloned().fold(0.0, f64::max),
        mean(&full_us),
    );

    // ---- fused qfwd weight-cache: hit vs miss -------------------------
    let mut qflat = vec![0u32; flat.len()];
    for t in &manifest.tensors {
        let seg = &flat[t.offset..t.offset + t.numel];
        let qp = QuantParams::from_data(seg, K);
        qflat[t.offset..t.offset + t.numel].copy_from_slice(&quantize(seg, &qp));
    }
    let one = &images[..manifest.input_numel()];
    batched.execute_quantized_versioned(one, 1, &qflat, K, 1)?; // prime
    let t_hit = bench(
        || batched.execute_quantized_versioned(one, 1, &qflat, K, 1).map(|_| ()),
        9,
    )?;
    let t_fwd = bench(|| batched.execute(one, 1, &flat).map(|_| ()), 9)?;
    let miss_extra = {
        // an unversioned call re-runs Eq. 5 every time
        let t = bench(|| batched.execute_quantized(one, 1, &qflat, K).map(|_| ()), 9)?;
        t - t_fwd
    };
    println!(
        "qfwd batch-1: cache hit {:.1} us (plain fwd {:.1} us), Eq.5 re-dequant adds {:.1} us",
        t_hit * 1e6,
        t_fwd * 1e6,
        miss_extra.max(0.0) * 1e6,
    );

    // ---- BENCH_runtime.json -------------------------------------------
    let report = json::obj(vec![
        ("model", json::s("mlp256")),
        ("params", json::num(flat.len() as f64)),
        ("batch", json::num(batch as f64)),
        ("scalar_imgs_per_s", json::num(batch as f64 / t_scalar)),
        ("batched_imgs_per_s", json::num(batch as f64 / t_batched)),
        ("speedup", json::num(speedup)),
        ("threads", json::num(threads as f64)),
        ("threaded_imgs_per_s", json::num(batch as f64 / t_pooled)),
        (
            "stage_upgrade_us",
            json::obj(vec![
                ("mean", json::num(mean(&delta_us))),
                ("max", json::num(delta_us.iter().cloned().fold(0.0, f64::max))),
                (
                    "per_stage",
                    json::arr(delta_us.iter().map(|&v| json::num(v)).collect()),
                ),
            ]),
        ),
        ("stage_full_redequant_us_mean", json::num(mean(&full_us))),
        ("qfwd_cache_hit_us", json::num(t_hit * 1e6)),
        ("qfwd_redequant_extra_us", json::num(miss_extra.max(0.0) * 1e6)),
    ]);
    std::fs::write("BENCH_runtime.json", report.to_string())?;
    println!("wrote BENCH_runtime.json");

    if std::env::var_os("PROGNET_BENCH_NO_ASSERT").is_none() {
        assert!(
            speedup >= 1.0,
            "batched path slower than the scalar oracle: {speedup:.2}x"
        );
    }

    // ---- classic per-model table on the real zoo (artifacts only) -----
    if prognet::artifacts_available() {
        artifact_table()?;
    } else {
        println!("(artifacts not built: skipping the real-zoo latency table)");
    }
    Ok(())
}

/// The original artifact-backed latency table (real models, selected
/// backend), including the fused-dequant path.
fn artifact_table() -> prognet::Result<()> {
    use prognet::eval::EvalSet;
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;
    let mut table = Table::new(
        &format!("{} backend latency (best of 5)", engine.backend_name()),
        &["model", "path", "batch", "latency", "images/s"],
    );
    for name in ["mlp", "cnn", "widecnn", "detector"] {
        let manifest = registry.get(name)?;
        let eval = EvalSet::load_named(&manifest.dataset)?;
        let session = ModelSession::load(&engine, manifest)?;
        let flat = manifest.load_weights()?;
        for batch in [1usize, 32, 256] {
            let images = eval.image_batch(batch.min(eval.n)).to_vec();
            let n = batch.min(eval.n);
            let t = bench(|| session.infer(&images, n, &flat).map(|_| ()), 5)?;
            table.row(vec![
                name.into(),
                "fwd".into(),
                batch.to_string(),
                format!("{:.2} ms", t * 1e3),
                format!("{:.0}", n as f64 / t),
            ]);
        }
        // fused qfwd (dequant inside the backend: the Pallas kernel on
        // pjrt, Eq. 5 in the interpreter) at batch 32
        if session.has_qfwd() {
            let mut qflat = vec![0u32; flat.len()];
            for t in &manifest.tensors {
                let seg = &flat[t.offset..t.offset + t.numel];
                let qp = QuantParams::from_data(seg, K);
                qflat[t.offset..t.offset + t.numel].copy_from_slice(&quantize(seg, &qp));
            }
            let n = 32;
            let images = eval.image_batch(n).to_vec();
            let t = bench(
                || session.infer_quantized(&images, n, &qflat, K).map(|_| ()),
                3,
            )?;
            table.row(vec![
                name.into(),
                "qfwd (fused dequant)".into(),
                "32".into(),
                format!("{:.2} ms", t * 1e3),
                format!("{:.0}", n as f64 / t),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "note: qfwd embeds the interpret-mode Pallas dequant + matmul kernels\n\
         in the HLO — correctness-path on CPU; real-TPU perf is estimated in\n\
         DESIGN.md §3 (VMEM/roofline), not measurable on the CPU plugin."
    );
    Ok(())
}
