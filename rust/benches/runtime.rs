//! Runtime micro-benchmarks: executable latency per model and batch
//! size, plus the fused-dequant (qfwd) variant, on the selected backend
//! (`PROGNET_BACKEND=reference|pjrt`; reference is the default).

use std::time::Instant;

use prognet::eval::EvalSet;
use prognet::metrics::Table;
use prognet::models::Registry;
use prognet::quant::{quantize, QuantParams, K};
use prognet::runtime::{Engine, ModelSession};

fn bench<F: FnMut() -> prognet::Result<()>>(mut f: F, reps: usize) -> prognet::Result<f64> {
    // warmup
    f()?;
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64());
    }
    Ok(best)
}

fn main() -> prognet::Result<()> {
    if !prognet::artifacts_available() {
        eprintln!("runtime: artifacts not built, skipping");
        return Ok(());
    }
    let engine = Engine::global()?;
    let registry = Registry::open_default()?;

    let mut table = Table::new(
        &format!("{} backend latency (best of 5)", engine.backend_name()),
        &["model", "path", "batch", "latency", "images/s"],
    );
    for name in ["mlp", "cnn", "widecnn", "detector"] {
        let manifest = registry.get(name)?;
        let eval = EvalSet::load_named(&manifest.dataset)?;
        let session = ModelSession::load(&engine, manifest)?;
        let flat = manifest.load_weights()?;
        for batch in [1usize, 32, 256] {
            let images = eval.image_batch(batch.min(eval.n)).to_vec();
            let n = batch.min(eval.n);
            let t = bench(|| session.infer(&images, n, &flat).map(|_| ()), 5)?;
            table.row(vec![
                name.into(),
                "fwd".into(),
                batch.to_string(),
                format!("{:.2} ms", t * 1e3),
                format!("{:.0}", n as f64 / t),
            ]);
        }
        // fused qfwd (dequant inside the backend: the Pallas kernel on
        // pjrt, Eq. 5 in the interpreter) at batch 32
        if session.has_qfwd() {
            let mut qflat = vec![0u32; flat.len()];
            for t in &manifest.tensors {
                let seg = &flat[t.offset..t.offset + t.numel];
                let qp = QuantParams::from_data(seg, K);
                qflat[t.offset..t.offset + t.numel]
                    .copy_from_slice(&quantize::quantize(seg, &qp));
            }
            let n = 32;
            let images = eval.image_batch(n).to_vec();
            let t = bench(
                || session.infer_quantized(&images, n, &qflat, K).map(|_| ()),
                3,
            )?;
            table.row(vec![
                name.into(),
                "qfwd (fused dequant)".into(),
                "32".into(),
                format!("{:.2} ms", t * 1e3),
                format!("{:.0}", n as f64 / t),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "note: qfwd embeds the interpret-mode Pallas dequant + matmul kernels\n\
         in the HLO — correctness-path on CPU; real-TPU perf is estimated in\n\
         DESIGN.md §3 (VMEM/roofline), not measurable on the CPU plugin."
    );
    Ok(())
}
