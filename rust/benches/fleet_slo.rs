//! Fleet SLO bench: the load generator against a self-hosted serving
//! tier, emitting `BENCH_fleet.json` so later PRs can track fleet-scale
//! serving across the trajectory.
//!
//! Four phases, same client mix each time:
//!   direct        — clients → a sharded origin reactor (the pre-cluster
//!                   baseline, kept for trend continuity)
//!   cluster_cold  — clients → router → edge prefix caches → origin,
//!                   edges empty (the first fetch pays the fill)
//!   cluster_warm  — same cluster again, edges warm: stage-prefix bytes
//!                   are served from the edges, the origin only streams
//!                   tails
//!   cluster_chaos — a warm *faultable* cluster (2 origins, 2 edges)
//!                   with a scripted kill/restart of the hot origin and
//!                   the hot edge landing mid-run: every client must
//!                   still finish, and accept→ModelReady p99 must stay
//!                   within 3× the fault-free warm phase
//!
//! The JSON carries all four SLO reports (cluster ones with per-tier
//! counter rows), a `tiered_ttfi` summary (accept→first-ModelReady p50
//! per phase) and `warm_prefix_offload` — the warm-phase fraction of
//! stage-prefix bytes served from edge caches, the PR's >= 50%
//! acceptance number.
//!
//! Runs entirely on the synthetic executable fixture (no artifacts).
//! Scale knobs (for CI smoke vs. local soak):
//!   PROGNET_FLEET_CLIENTS  total virtual clients per phase (default 200)
//!   PROGNET_FLEET_WORKERS  reactor shards (default 2)
//!   PROGNET_BENCH_NO_ASSERT  skip the acceptance asserts

use std::sync::Arc;
use std::time::Duration;

use prognet::fleet::chaos::{self, ChaosScript};
use prognet::fleet::cluster::{Cluster, ClusterConfig};
use prognet::fleet::loadgen::{run_fleet, FleetOptions, Scenario};
use prognet::fleet::placement::{HashRing, DEFAULT_VNODES};
use prognet::fleet::slo::{SloReport, TierStats};
use prognet::fleet::FleetConfig;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::{open_fetch, ServerConfig};
use prognet::server::{FetchRequest, Repository, Server};
use prognet::testutil::fixture;
use prognet::util::json;
use prognet::util::sync::Clock;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn ttfi_p50(report: &SloReport) -> f64 {
    report
        .overall
        .model_ready
        .as_ref()
        .map(|q| q.p50)
        .unwrap_or(f64::NAN)
}

fn ttfi_p99(report: &SloReport) -> f64 {
    report
        .overall
        .model_ready
        .as_ref()
        .map(|q| q.p99)
        .unwrap_or(f64::NAN)
}

/// Warm-phase offload: of the stage-prefix bytes sourced during the warm
/// run (edge-cache-served + origin fills), the cached fraction.
fn delta_offload(before: &TierStats, after: &TierStats) -> Option<f64> {
    let cache = after.cache_bytes - before.cache_bytes;
    let fill = after.fill_bytes - before.fill_bytes;
    if cache + fill == 0 {
        None
    } else {
        Some(cache as f64 / (cache + fill) as f64)
    }
}

fn main() -> prognet::Result<()> {
    let clients = env_usize("PROGNET_FLEET_CLIENTS", 200);
    let workers = env_usize("PROGNET_FLEET_WORKERS", 2);

    let reg = fixture::executable_models("bench-fleet")?;
    let manifest = reg.get("dense3")?.clone();
    let repo = Arc::new(Repository::new(reg));
    let runtime = Arc::new(ModelSession::load(&Engine::reference(), &manifest)?);

    // the reference mix (70% @0.5 MB/s, 20% @0.1, 10% flaky-reconnect),
    // shared with `prognet fleet` and CI so BENCH trends stay comparable
    let scenario = Scenario::mix("dense3", clients);
    let opts = FleetOptions {
        ramp: Duration::from_millis(300),
        // past the manifest of the ~2 KB dense3 container, so the
        // severed first connection resumes at a stage boundary
        flaky_cut_bytes: 1500,
        connect_retries: 5,
        ..FleetOptions::default()
    };
    let mix: Vec<String> = scenario
        .cohorts
        .iter()
        .map(|c| format!("{}×{}", c.clients, c.name))
        .collect();
    println!(
        "fleet_slo: {} clients ({}) per phase, {workers} shards",
        scenario.total_clients(),
        mix.join(", ")
    );

    // ---- phase 1: direct to a single origin reactor -------------------
    let server = Server::start_fleet(
        "127.0.0.1:0",
        repo.clone(),
        ServerConfig {
            workers,
            ..ServerConfig::default()
        },
        FleetConfig {
            write_burst: 1024, // keep the small fixture bodies honestly paced
            ..FleetConfig::default()
        },
    )?;
    println!("\n== phase: direct (clients -> origin) ==");
    let direct = run_fleet(server.addr(), &scenario, Some(runtime.clone()), &opts)?;
    println!("{}", direct.render());
    println!("{}", server.stats().table().render());
    drop(server);

    // ---- phases 2+3: through the cluster tier -------------------------
    let cluster = Cluster::start(
        repo.clone(),
        ClusterConfig {
            origins: 1,
            edges: 2,
            workers_per_origin: workers,
            prefix_stages: 2,
            fleet: FleetConfig {
                write_burst: 1024,
                ..FleetConfig::default()
            },
            ..ClusterConfig::default()
        },
    )?;
    println!("\n== phase: cluster_cold (clients -> router -> edges -> origin) ==");
    let cold = run_fleet(cluster.addr(), &scenario, Some(runtime.clone()), &opts)?
        .with_tiers(cluster.tiers());
    println!("{}", cold.render());
    let tiers_after_cold = cluster.tiers();

    println!("\n== phase: cluster_warm (edges pre-filled) ==");
    let warm = run_fleet(cluster.addr(), &scenario, Some(runtime.clone()), &opts)?
        .with_tiers(cluster.tiers());
    println!("{}", warm.render());
    drop(cluster);

    // ---- phase 4: warm faultable cluster under scripted chaos ---------
    let chaos_cluster = Cluster::start(
        repo,
        ClusterConfig {
            origins: 2,
            edges: 2,
            workers_per_origin: workers,
            prefix_stages: 2,
            faultable: true,
            // tier retries back off on virtual time; recovery comes from
            // failover, not from sleeping out the outage
            clock: Clock::manual(),
            fleet: FleetConfig {
                write_burst: 1024,
                ..FleetConfig::default()
            },
            ..ClusterConfig::default()
        },
    )?;
    // pre-warm so the script hits a serving tree, then aim the kills at
    // the instances that actually carry dense3 (placement is model-keyed)
    for _ in 0..4 {
        let (mut s, _) = open_fetch(&chaos_cluster.addr(), &FetchRequest::new("dense3"))?;
        let mut sink = Vec::new();
        std::io::Read::read_to_end(&mut s, &mut sink)?;
    }
    let hot = |prefix: &str| {
        let labels: Vec<String> = (0..2).map(|i| format!("{prefix}-{i}")).collect();
        HashRing::new(&labels, DEFAULT_VNODES).place("dense3").unwrap()
    };
    let (ho, he) = (hot("origin"), hot("edge"));
    let script = ChaosScript::parse(&format!(
        "kill:origin:{ho}@150,restart:origin:{ho}@600,kill:edge:{he}@800,restart:edge:{he}@1100"
    ))?;
    let chaos_opts = FleetOptions {
        // arrivals span every outage window in the script
        ramp: Duration::from_millis(1500),
        ..opts.clone()
    };
    println!("\n== phase: cluster_chaos (scripted origin/edge kill + restart) ==");
    let chaos_report = std::thread::scope(|s| -> prognet::Result<SloReport> {
        let cl = &chaos_cluster;
        let sc = &script;
        let h = s.spawn(move || chaos::apply(cl, sc, &Clock::real()));
        let report = run_fleet(cl.addr(), &scenario, Some(runtime), &chaos_opts)?;
        for line in h.join().expect("chaos thread panicked")? {
            println!("chaos: {line}");
        }
        Ok(report)
    })?
    .with_tiers(chaos_cluster.tiers());
    println!("{}", chaos_report.render());

    let edge_cold = tiers_after_cold.iter().find(|t| t.name == "edge").unwrap();
    let edge_warm = warm.tiers.iter().find(|t| t.name == "edge").unwrap();
    let warm_offload = delta_offload(edge_cold, edge_warm);

    let ttfi = json::obj(vec![
        ("direct_s", json::num(ttfi_p50(&direct))),
        ("cluster_cold_s", json::num(ttfi_p50(&cold))),
        ("cluster_warm_s", json::num(ttfi_p50(&warm))),
        ("cluster_chaos_s", json::num(ttfi_p50(&chaos_report))),
    ]);
    println!(
        "tiered TTFI p50: direct {:.4}s | cluster cold {:.4}s | cluster warm {:.4}s \
         | cluster chaos {:.4}s",
        ttfi_p50(&direct),
        ttfi_p50(&cold),
        ttfi_p50(&warm),
        ttfi_p50(&chaos_report)
    );
    println!(
        "chaos TTFI p99 {:.4}s vs warm p99 {:.4}s",
        ttfi_p99(&chaos_report),
        ttfi_p99(&warm)
    );
    if let Some(v) = warm_offload {
        println!("warm stage-prefix offload: {:.1}% served from edges", v * 100.0);
    }

    let mut fields = vec![
        ("direct", direct.to_json()),
        ("cluster_cold", cold.to_json()),
        ("cluster_warm", warm.to_json()),
        ("cluster_chaos", chaos_report.to_json()),
        ("tiered_ttfi", ttfi),
    ];
    if let Some(v) = warm_offload {
        fields.push(("warm_prefix_offload", json::num(v)));
    }
    std::fs::write("BENCH_fleet.json", json::obj(fields).to_string())?;
    println!("wrote BENCH_fleet.json");

    if std::env::var_os("PROGNET_BENCH_NO_ASSERT").is_none() {
        let phases = [
            ("direct", &direct),
            ("cluster_cold", &cold),
            ("cluster_warm", &warm),
            ("cluster_chaos", &chaos_report),
        ];
        for (phase, report) in phases {
            assert_eq!(report.clients(), scenario.total_clients(), "{phase}");
            assert_eq!(
                report.protocol_errors(),
                0,
                "{phase} hit protocol errors: {:?}",
                report.sample_errors
            );
            assert_eq!(
                report.overall.finished,
                scenario.total_clients(),
                "{phase}: uncapped serving tier must serve everyone"
            );
        }
        let v = warm_offload.expect("warm phase served stage-prefix bytes");
        assert!(
            v >= 0.5,
            "warm edges must offload >= 50% of stage-prefix bytes, got {v:.3}"
        );
        // the chaos script must genuinely land (and be recovered from) …
        let retries: u64 = chaos_report.tiers.iter().map(|t| t.retries).sum();
        let failovers: u64 = chaos_report.tiers.iter().map(|t| t.failovers).sum();
        assert!(
            retries + failovers >= 1,
            "chaos phase exercised no tier retries or failovers"
        );
        // … without blowing the latency budget: p99 within 3× fault-free warm
        let (chaos_p99, warm_p99) = (ttfi_p99(&chaos_report), ttfi_p99(&warm));
        assert!(
            chaos_p99 <= 3.0 * warm_p99,
            "chaos TTFI p99 {chaos_p99:.4}s exceeds 3x warm p99 {warm_p99:.4}s"
        );
    }
    println!(
        "§Perf target: accept→first-ModelReady p99 stays flat as the client count\n\
         grows, cluster_warm TTFI tracks direct while the origin streams only\n\
         tails, and cluster_chaos p99 stays within 3x warm despite scripted\n\
         kill/restarts; track tiered_ttfi + warm_prefix_offload in BENCH_fleet.json."
    );
    Ok(())
}
