//! Fleet SLO bench: the load generator against a self-hosted reactor,
//! emitting `BENCH_fleet.json` so later PRs can track fleet-scale
//! serving (clients, throughput mix, accept→first-`ModelReady`
//! p50/p99) across the trajectory.
//!
//! Runs entirely on the synthetic executable fixture (no artifacts).
//! Scale knobs (for CI smoke vs. local soak):
//!   PROGNET_FLEET_CLIENTS  total virtual clients (default 200)
//!   PROGNET_FLEET_WORKERS  reactor shards (default 2)
//!   PROGNET_BENCH_NO_ASSERT  skip the zero-protocol-error assert

use std::sync::Arc;
use std::time::Duration;

use prognet::fleet::loadgen::{run_fleet, FleetOptions, Scenario};
use prognet::fleet::FleetConfig;
use prognet::runtime::{Engine, ModelSession};
use prognet::server::service::ServerConfig;
use prognet::server::{Repository, Server};
use prognet::testutil::fixture;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> prognet::Result<()> {
    let clients = env_usize("PROGNET_FLEET_CLIENTS", 200);
    let workers = env_usize("PROGNET_FLEET_WORKERS", 2);

    let reg = fixture::executable_models("bench-fleet")?;
    let manifest = reg.get("dense3")?.clone();
    let repo = Arc::new(Repository::new(reg));
    let server = Server::start_fleet(
        "127.0.0.1:0",
        repo,
        ServerConfig {
            workers,
            ..ServerConfig::default()
        },
        FleetConfig {
            write_burst: 1024, // keep the small fixture bodies honestly paced
            ..FleetConfig::default()
        },
    )?;
    let runtime = Arc::new(ModelSession::load(&Engine::reference(), &manifest)?);

    // the reference mix (70% @0.5 MB/s, 20% @0.1, 10% flaky-reconnect),
    // shared with `prognet fleet` and CI so BENCH trends stay comparable
    let scenario = Scenario::mix("dense3", clients);
    let opts = FleetOptions {
        ramp: Duration::from_millis(300),
        // past the manifest of the ~2 KB dense3 container, so the
        // severed first connection resumes at a stage boundary
        flaky_cut_bytes: 1500,
        connect_retries: 5,
        ..FleetOptions::default()
    };
    let mix: Vec<String> = scenario
        .cohorts
        .iter()
        .map(|c| format!("{}×{}", c.clients, c.name))
        .collect();
    println!(
        "fleet_slo: {} clients ({}) on {workers} shards",
        scenario.total_clients(),
        mix.join(", ")
    );
    let report = run_fleet(server.addr(), &scenario, Some(runtime), &opts)?;
    println!("{}", report.render());
    println!("{}", server.stats().table().render());

    std::fs::write("BENCH_fleet.json", report.to_json().to_string())?;
    println!("wrote BENCH_fleet.json");

    if std::env::var_os("PROGNET_BENCH_NO_ASSERT").is_none() {
        assert_eq!(report.clients(), scenario.total_clients());
        assert_eq!(
            report.protocol_errors(),
            0,
            "fleet run hit protocol errors: {:?}",
            report.sample_errors
        );
        assert_eq!(
            report.overall.finished,
            scenario.total_clients(),
            "uncapped server must serve everyone"
        );
    }
    println!(
        "§Perf target: accept→first-ModelReady p99 stays flat as the client count\n\
         grows; track accept_to_model_ready in BENCH_fleet.json across PRs."
    );
    Ok(())
}
