//! Offline stub of the `xla` (xla-rs) crate API surface that
//! `prognet::runtime::pjrt` uses.
//!
//! The real crate links `xla_extension` (a native PJRT build) and cannot
//! be resolved or built in an offline container. This stub keeps the
//! `pjrt` feature *compiling* everywhere: every entry point returns
//! [`Error::StubOnly`] at runtime, so selecting the PJRT backend in a
//! stub build fails loudly at client construction — never silently.
//!
//! To run on real PJRT, point the `xla` dependency of `prognet` at an
//! actual `xla-rs` checkout (same API) instead of this path.

use std::fmt;

/// Stub error: the only error this crate ever produces.
#[derive(Debug, Clone)]
pub enum Error {
    /// Raised by every operation — this build carries no PJRT runtime.
    StubOnly,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: this build has no PJRT runtime (replace the `xla` \
             path dependency with a real xla-rs checkout, or use the \
             reference backend)"
        )
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for u32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host-side tensor value (stub: never actually constructed).
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    /// Rank-1 literal from a slice (stub: the data is dropped — a stub
    /// literal can never reach a real execution anyway).
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::StubOnly)
    }

    /// Copy out as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::StubOnly)
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::StubOnly)
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::StubOnly)
    }
}

/// An XLA computation (stub).
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device-resident buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::StubOnly)
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given inputs.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::StubOnly)
    }
}

/// A PJRT client (stub).
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU client — always fails in the stub, which is the
    /// single choke point that keeps stub builds honest.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::StubOnly)
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::StubOnly)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = Error::StubOnly.to_string();
        assert!(msg.contains("stub"));
    }
}
