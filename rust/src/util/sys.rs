//! The crate's quarantine for raw OS calls that need `unsafe`.
//!
//! Everything `unsafe` outside FFI-backend code lives either here or in
//! [`crate::fleet::poll`] (the `poll(2)` wrapper) — the allowlist
//! enforced by `prognet-lint` rule `unsafe-outside-allowlist` and by
//! `#![forbid(unsafe_code)]` on every other module.

use std::net::TcpStream;

use anyhow::Result;

/// Shrink a socket's kernel receive buffer so an unread stream actually
/// stalls the sender.
///
/// Raw `setsockopt` with the common Linux constants inlined — `anyhow`
/// is the crate's only dependency, so no `libc`. The constants differ on
/// mips/sparc, so those arches (and non-Linux platforms) take the no-op
/// path below: the call is best-effort backpressure shaping for the
/// serial-mode ablation, not a correctness requirement.
#[cfg(all(
    any(target_os = "linux", target_os = "android"),
    not(any(target_arch = "mips", target_arch = "mips64", target_arch = "sparc64"))
))]
pub fn shrink_recv_buffer(stream: &TcpStream) -> Result<()> {
    use std::os::fd::AsRawFd;
    const SOL_SOCKET: i32 = 1;
    const SO_RCVBUF: i32 = 8;
    extern "C" {
        fn setsockopt(
            fd: i32,
            level: i32,
            optname: i32,
            optval: *const core::ffi::c_void,
            optlen: u32,
        ) -> i32;
    }
    let fd = stream.as_raw_fd();
    let size: i32 = 16 * 1024;
    let rc = unsafe {
        setsockopt(
            fd,
            SOL_SOCKET,
            SO_RCVBUF,
            &size as *const i32 as *const core::ffi::c_void,
            std::mem::size_of::<i32>() as u32,
        )
    };
    anyhow::ensure!(rc == 0, "setsockopt(SO_RCVBUF) failed");
    Ok(())
}

/// No-op on platforms where the inlined constants don't apply.
#[cfg(not(all(
    any(target_os = "linux", target_os = "android"),
    not(any(target_arch = "mips", target_arch = "mips64", target_arch = "sparc64"))
)))]
pub fn shrink_recv_buffer(_stream: &TcpStream) -> Result<()> {
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrink_applies_to_a_live_socket() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        let _accepted = listener.accept().unwrap();
        shrink_recv_buffer(&stream).unwrap();
    }
}
