//! Generic single-flight computation cache.
//!
//! `get_or_compute(key, f)` guarantees that when N threads miss the
//! cache for the same key simultaneously, exactly one (the *leader*)
//! runs `f` while the rest (*followers*) wait on the flight and share
//! the leader's result. Successes are cached; errors are returned to
//! every waiter of that flight but **not** cached, so a later request
//! retries. A leader that panics unwedges the key on unwind (followers
//! get an error instead of blocking forever).
//!
//! Built on the [`crate::util::sync`] facade, so the whole protocol is
//! explorable by the model checker (`tests/schedules.rs` hammers it with
//! a concurrent stampede under `--cfg prognet_check`).

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::hash::Hash;

use crate::util::sync::{Arc, Condvar, Mutex};

/// A pending computation that concurrent requesters wait on.
struct Flight<V> {
    done: Mutex<Option<Result<V, String>>>,
    cv: Condvar,
}

impl<V: Clone> Flight<V> {
    fn new() -> Self {
        Self {
            done: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, result: Result<V, String>) {
        *self.done.lock().unwrap() = Some(result);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<V, String> {
        let mut guard = self.done.lock().unwrap();
        while guard.is_none() {
            guard = self.cv.wait(guard).unwrap();
        }
        guard.clone().unwrap()
    }
}

enum Slot<V> {
    Ready(V),
    Pending(Arc<Flight<V>>),
}

/// Unwedges a single-flight key if the leader unwinds: without this, a
/// panic inside the compute closure would leave the `Pending` slot in
/// place and every follower (and all future requests for the key)
/// blocked forever. Disarmed by `take()`-ing the key on the normal path.
struct FlightCleanup<'a, K: Eq + Hash, V: Clone> {
    slots: &'a Mutex<HashMap<K, Slot<V>>>,
    key: Option<K>,
}

impl<K: Eq + Hash, V: Clone> Drop for FlightCleanup<'_, K, V> {
    fn drop(&mut self) {
        let Some(key) = self.key.take() else { return };
        // avoid unwrap: a poisoned lock during unwind must not double-panic
        if let Ok(mut slots) = self.slots.lock() {
            if let Some(Slot::Pending(flight)) = slots.remove(&key) {
                flight.complete(Err(
                    "single-flight compute panicked; request again to retry".to_string()
                ));
            }
        }
    }
}

/// Keyed single-flight cache. `V` is typically an `Arc<...>` so all
/// callers share one allocation.
pub struct SingleFlight<K, V> {
    slots: Mutex<HashMap<K, Slot<V>>>,
}

impl<K: Eq + Hash + Clone, V: Clone> SingleFlight<K, V> {
    pub fn new() -> Self {
        Self {
            slots: Mutex::new(HashMap::new()),
        }
    }

    /// Cached value for `key`, or run `compute` (exactly once across all
    /// concurrent callers of the same key) and cache its success.
    pub fn get_or_compute<F>(&self, key: K, compute: F) -> Result<V, String>
    where
        F: FnOnce() -> Result<V, String>,
    {
        let existing_flight = {
            let mut slots = self.slots.lock().unwrap();
            match slots.get(&key) {
                Some(Slot::Ready(v)) => return Ok(v.clone()),
                Some(Slot::Pending(f)) => Some(f.clone()),
                None => {
                    slots.insert(key.clone(), Slot::Pending(Arc::new(Flight::new())));
                    None
                }
            }
        };

        if let Some(flight) = existing_flight {
            // follower: another thread is already computing this key
            return flight.wait();
        }

        // leader: compute outside the slot lock, then publish
        let mut panic_guard = FlightCleanup {
            slots: &self.slots,
            key: Some(key),
        };
        let result = compute();
        let key = panic_guard.key.take().expect("guard still armed");
        let flight = {
            let mut slots = self.slots.lock().unwrap();
            let flight = match slots.remove(&key) {
                Some(Slot::Pending(f)) => Some(f),
                _ => None,
            };
            if let Ok(v) = &result {
                slots.insert(key, Slot::Ready(v.clone()));
            }
            // on error the slot stays removed, so a later request retries
            flight
        };
        if let Some(flight) = flight {
            flight.complete(result.clone());
        }
        result
    }

    /// Drop a cached value so the next request recomputes it. Only
    /// `Ready` slots are removed: an in-flight `Pending` computation is
    /// left alone (removing it would orphan the leader's publish step and
    /// wedge followers), so a racing invalidate simply lets the flight
    /// land and a later invalidate can flush it. Returns whether a cached
    /// value was dropped.
    pub fn invalidate(&self, key: &K) -> bool {
        let mut slots = self.slots.lock().unwrap();
        match slots.get(key) {
            Some(Slot::Ready(_)) => {
                slots.remove(key);
                true
            }
            _ => false,
        }
    }

    /// Peek a cached (`Ready`) value without computing. `Pending` keys
    /// return `None` — peeking must never block on a flight.
    pub fn get(&self, key: &K) -> Option<V> {
        let slots = self.slots.lock().unwrap();
        match slots.get(key) {
            Some(Slot::Ready(v)) => Some(v.clone()),
            _ => None,
        }
    }

    /// Replace (or seed) the cached value for a key, bypassing the
    /// flight. Used by fault injection to plant corrupted entries and by
    /// tests; production fills go through `get_or_compute`.
    pub fn insert(&self, key: K, value: V) {
        self.slots.lock().unwrap().insert(key, Slot::Ready(value));
    }

    /// Number of completed (cached) entries.
    pub fn ready_len(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, Slot::Ready(_)))
            .count()
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Default for SingleFlight<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicUsize, Ordering};
    use crate::util::sync::Barrier;

    #[test]
    fn stampede_computes_once() {
        let sf = Arc::new(SingleFlight::<u32, Arc<Vec<u8>>>::new());
        let computes = Arc::new(AtomicUsize::new(0));
        let barrier = Arc::new(Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let sf = sf.clone();
                let computes = computes.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    sf.get_or_compute(7, || {
                        computes.fetch_add(1, Ordering::SeqCst);
                        Ok(Arc::new(vec![1, 2, 3]))
                    })
                    .unwrap()
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(computes.load(Ordering::SeqCst), 1, "cache stampede");
        for r in &results[1..] {
            assert!(Arc::ptr_eq(&results[0], r), "all callers share one Arc");
        }
        assert_eq!(sf.ready_len(), 1);
    }

    #[test]
    fn errors_are_not_cached() {
        let sf = SingleFlight::<u32, u32>::new();
        let computes = AtomicUsize::new(0);
        let r = sf.get_or_compute(1, || {
            computes.fetch_add(1, Ordering::SeqCst);
            Err("boom".to_string())
        });
        assert_eq!(r, Err("boom".to_string()));
        assert_eq!(sf.ready_len(), 0);
        let r = sf.get_or_compute(1, || {
            computes.fetch_add(1, Ordering::SeqCst);
            Ok(42)
        });
        assert_eq!(r, Ok(42));
        assert_eq!(computes.load(Ordering::SeqCst), 2, "error must retry");
        assert_eq!(sf.ready_len(), 1);
    }

    #[test]
    fn invalidate_drops_ready_but_not_pending() {
        let sf = SingleFlight::<u32, u32>::new();
        assert!(!sf.invalidate(&1), "nothing cached yet");
        sf.get_or_compute(1, || Ok(10)).unwrap();
        assert_eq!(sf.ready_len(), 1);
        assert!(sf.invalidate(&1));
        assert_eq!(sf.ready_len(), 0);
        // next request recomputes
        assert_eq!(sf.get_or_compute(1, || Ok(20)), Ok(20));
    }

    #[test]
    fn get_peeks_and_insert_replaces() {
        let sf = SingleFlight::<u32, u32>::new();
        assert_eq!(sf.get(&1), None);
        sf.get_or_compute(1, || Ok(10)).unwrap();
        assert_eq!(sf.get(&1), Some(10));
        sf.insert(1, 99);
        assert_eq!(sf.get(&1), Some(99));
        // insert seeds a fresh key too
        sf.insert(2, 7);
        assert_eq!(sf.get_or_compute(2, || Ok(0)), Ok(7));
        assert_eq!(sf.ready_len(), 2);
    }

    #[test]
    fn leader_panic_unwedges_the_key() {
        let sf = Arc::new(SingleFlight::<u32, u32>::new());
        let entered = Arc::new(Barrier::new(2));
        let leader = {
            let sf = sf.clone();
            let entered = entered.clone();
            std::thread::spawn(move || {
                let _ = sf.get_or_compute(5, || {
                    entered.wait();
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    panic!("injected leader panic");
                });
            })
        };
        entered.wait(); // leader holds the Pending slot from here on
        // follower either waits out the flight (gets the panic error) or
        // arrives after cleanup and becomes a fresh leader (gets Ok)
        let r = sf.get_or_compute(5, || Ok(99));
        match r {
            Err(msg) => assert!(msg.contains("panicked"), "unexpected error: {msg}"),
            Ok(v) => assert_eq!(v, 99),
        }
        assert!(leader.join().is_err(), "leader must have panicked");
        // key is not wedged: a retry returns the cached follower value or
        // computes fresh
        let retry = sf.get_or_compute(5, || Ok(11)).unwrap();
        assert!(retry == 11 || retry == 99, "key wedged after panic");
    }
}
