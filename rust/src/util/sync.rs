//! Synchronization facade: the single import point for sync primitives
//! and time sources crate-wide.
//!
//! In normal builds every item is a verbatim re-export of `std::sync` /
//! `std::time` — zero overhead, zero behavior change. Under
//! `--cfg prognet_check` the lock, condvar and atomic types are swapped
//! for the instrumented shims in [`crate::analysis::shim`], which report
//! every operation to the deterministic scheduler so the model-check
//! suite (`tests/schedules.rs`) can explore interleavings.
//!
//! Repo invariant (enforced by `prognet-lint` rule `direct-sync-import`):
//! concurrency-touching modules import `Mutex`/`Condvar`/`RwLock`/atomics
//! from here, never from `std::sync` directly. `Arc`, `Barrier`,
//! `OnceLock` and `mpsc` pass through unchanged in both modes (`Arc` is
//! memory management, not a schedule-relevant operation; channels are not
//! yet modeled — schedule tests use locks and condvars).
//!
//! Time goes through [`clock`]: `clock::now()` / `clock::sleep()` follow
//! the model's virtual clock inside a checked run, and the injectable
//! [`Clock`] handle lets timing-sensitive components (connection
//! deadlines, token-bucket pacing) run tests on manual virtual time in
//! ordinary builds too.

#![forbid(unsafe_code)]

pub use std::sync::{mpsc, Arc, Barrier, LockResult, OnceLock, PoisonError, TryLockError, Weak};

#[cfg(not(prognet_check))]
pub use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult,
};

#[cfg(prognet_check)]
pub use crate::analysis::shim::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult,
};

/// Atomics facade: `util::sync::atomic::{AtomicU64, Ordering, ...}`.
pub mod atomic {
    #[cfg(not(prognet_check))]
    pub use std::sync::atomic::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };

    #[cfg(prognet_check)]
    pub use crate::analysis::shim::{
        AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

/// Time facade: wall-clock reads and sleeps that follow the model
/// checker's virtual clock inside a checked run.
pub mod clock {
    use std::time::{Duration, Instant};

    /// Current time. Inside a model run this is the scheduler's virtual
    /// clock (starts at the run's base instant, advances only when every
    /// model thread is parked on a deadline); otherwise `Instant::now()`.
    pub fn now() -> Instant {
        crate::analysis::sched::virtual_now().unwrap_or_else(Instant::now)
    }

    /// Sleep. Inside a model run the thread parks on the virtual clock
    /// (no real time passes); otherwise `std::thread::sleep`.
    pub fn sleep(dur: Duration) {
        crate::analysis::sched::sleep(dur);
    }
}

use std::time::{Duration, Instant};

/// Injectable time source for components whose pacing/eviction logic
/// should be testable without real sleeps even in normal builds.
///
/// [`Clock::real`] delegates to [`clock::now`] / [`clock::sleep`] (and so
/// still follows the model's virtual clock under `prognet_check`).
/// [`Clock::manual`] is a shared virtual clock that only moves when
/// advanced — `sleep` advances it instead of blocking, so a pacing loop
/// runs at full speed while observing exactly the timeline the test
/// scripted.
#[derive(Clone, Debug)]
pub struct Clock(ClockInner);

#[derive(Clone, Debug)]
enum ClockInner {
    Real,
    Manual(Arc<ManualClock>),
}

#[derive(Debug)]
struct ManualClock {
    base: Instant,
    // Plain std atomic on purpose: the clock is test scaffolding, not a
    // protocol under check, and must not perturb explored schedules.
    offset_ns: std::sync::atomic::AtomicU64,
}

impl Clock {
    /// Wall-clock time (virtual inside a model run).
    pub fn real() -> Self {
        Clock(ClockInner::Real)
    }

    /// A virtual clock starting at `now()`; clones share the timeline.
    pub fn manual() -> Self {
        Clock(ClockInner::Manual(Arc::new(ManualClock {
            base: clock::now(),
            offset_ns: std::sync::atomic::AtomicU64::new(0),
        })))
    }

    pub fn now(&self) -> Instant {
        match &self.0 {
            ClockInner::Real => clock::now(),
            ClockInner::Manual(m) => {
                let ns = m.offset_ns.load(std::sync::atomic::Ordering::SeqCst);
                m.base + Duration::from_nanos(ns)
            }
        }
    }

    /// Real clock: blocks. Manual clock: advances the shared timeline
    /// instead (a paced writer "waits out" its budget instantly).
    pub fn sleep(&self, dur: Duration) {
        match &self.0 {
            ClockInner::Real => clock::sleep(dur),
            ClockInner::Manual(_) => self.advance(dur),
        }
    }

    /// Move a manual clock forward. No-op on a real clock (tests that
    /// accept either kind can advance unconditionally).
    pub fn advance(&self, dur: Duration) {
        if let ClockInner::Manual(m) = &self.0 {
            let ns = u64::try_from(dur.as_nanos()).unwrap_or(u64::MAX);
            m.offset_ns
                .fetch_add(ns, std::sync::atomic::Ordering::SeqCst);
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::real()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances_without_blocking() {
        let c = Clock::manual();
        let t0 = c.now();
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert_eq!(c.now() - t0, Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn manual_clock_clones_share_the_timeline() {
        let a = Clock::manual();
        let b = a.clone();
        b.advance(Duration::from_millis(250));
        assert_eq!(a.now(), b.now());
        assert_eq!(a.now() - b.now(), Duration::ZERO);
    }

    #[test]
    fn real_clock_advance_is_a_noop() {
        let c = Clock::real();
        let before = c.now();
        c.advance(Duration::from_secs(3600));
        let after = c.now();
        assert!(after.saturating_duration_since(before) < Duration::from_secs(10));
    }

    #[test]
    fn facade_types_are_usable() {
        let m = Mutex::new(1u32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 2);
        let rw = RwLock::new(vec![1, 2]);
        assert_eq!(rw.read().unwrap().len(), 2);
        rw.write().unwrap().push(3);
        assert_eq!(rw.read().unwrap().len(), 3);
        let a = atomic::AtomicU64::new(7);
        a.fetch_add(1, atomic::Ordering::SeqCst);
        assert_eq!(a.load(atomic::Ordering::SeqCst), 8);
    }
}
