//! CRC-32 (ISO-HDLC / zlib polynomial), vendored so the crate's only
//! external dependency stays `anyhow`.
//!
//! Bit-exact with `crc32fast::hash` and python's `zlib.crc32` — the
//! golden vectors under `artifacts/golden/` store CRCs computed by the
//! python encoder, and every `.pnet` fragment header carries one
//! (`format::FragmentHeader`), so the polynomial and reflection must
//! match exactly. Table-driven, one byte per step; fragment payloads are
//! small enough that a slice-by-8 implementation would be over-engineering.

#![forbid(unsafe_code)]

use crate::util::sync::OnceLock;

/// Reflected CRC-32 polynomial (0x04C11DB7 bit-reversed).
const POLY: u32 = 0xEDB8_8320;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, entry) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            }
            *entry = c;
        }
        t
    })
}

/// CRC-32 of `data` (init `0xFFFF_FFFF`, reflected, final xor) — the
/// classic zlib checksum.
pub fn hash(data: &[u8]) -> u32 {
    let t = table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the standard CRC-32 check value
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
        assert_eq!(hash(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = hash(&[0x00, 0x01, 0x02, 0x03]);
        let b = hash(&[0x00, 0x01, 0x02, 0x07]);
        assert_ne!(a, b);
    }
}
