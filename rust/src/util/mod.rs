//! Self-contained utility layer.
//!
//! The offline vendor set has no serde/clap/rand/tokio, so this module
//! provides the minimal, well-tested equivalents the rest of the crate
//! builds on: a JSON parser/writer, a CLI argument parser, PRNGs and
//! distributions, byte codecs, a thread pool, descriptive statistics and
//! a tiny logger.

pub mod bytes;
pub mod cli;
pub mod config;
pub mod crc32;
pub mod flight;
pub mod json;
pub mod logging;
pub mod pool;
pub mod retry;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod sys;
