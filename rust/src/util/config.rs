//! Launcher configuration: JSON config files with CLI overrides.
//!
//! `prognet serve --config serve.json --speed-mbps 2.0` loads the file,
//! then applies any explicitly passed flags on top — the standard
//! precedence (defaults < file < CLI).

#![forbid(unsafe_code)]

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::fleet::ShedPolicy;
use crate::quant::{Schedule, K};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Full server/launcher configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeFileConfig {
    pub addr: String,
    /// default bandwidth shaping (None = unshaped)
    pub speed_mbps: Option<f64>,
    /// reactor shard (event-loop worker) threads
    pub workers: usize,
    pub schedule: Schedule,
    /// models to pre-encode at startup (warm cache)
    pub preload: Vec<String>,
    /// admission cap on concurrent connections (None = unlimited)
    pub max_conns: Option<usize>,
    /// what happens over the cap: reject | queue:<ms> | degrade:<stages>
    pub shed_policy: ShedPolicy,
    /// seconds between live-counter log lines (0 = silent)
    pub log_interval_s: u64,
    /// runtime worker threads for batched execution (0 = auto from
    /// available parallelism; None = leave `PROGNET_THREADS` in charge)
    pub threads: Option<usize>,
}

impl Default for ServeFileConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7070".into(),
            speed_mbps: None,
            workers: 8,
            schedule: Schedule::paper_default(),
            preload: Vec::new(),
            max_conns: None,
            shed_policy: ShedPolicy::Reject,
            log_interval_s: 30,
            threads: None,
        }
    }
}

impl ServeFileConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        let obj = j.as_obj()?;
        for (key, val) in obj {
            match key.as_str() {
                "addr" => cfg.addr = val.as_str()?.to_string(),
                "speed_mbps" => {
                    cfg.speed_mbps = match val {
                        Json::Null => None,
                        v => Some(v.as_f64()?),
                    }
                }
                "workers" => cfg.workers = val.as_usize()?,
                "schedule" => {
                    let widths = val
                        .as_arr()?
                        .iter()
                        .map(|w| Ok(w.as_i64()? as u32))
                        .collect::<Result<Vec<_>>>()?;
                    cfg.schedule = Schedule::new(widths, K)?;
                }
                "preload" => {
                    cfg.preload = val
                        .as_arr()?
                        .iter()
                        .map(|m| Ok(m.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?;
                }
                "max_conns" => {
                    cfg.max_conns = match val {
                        Json::Null => None,
                        v => Some(v.as_usize()?),
                    }
                }
                "shed_policy" => cfg.shed_policy = ShedPolicy::parse(val.as_str()?)?,
                "log_interval_s" => cfg.log_interval_s = val.as_usize()? as u64,
                "threads" => cfg.threads = Some(val.as_usize()?),
                other => bail!("unknown config key '{other}'"),
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::load(path)?)
            .with_context(|| format!("in config {}", path.display()))
    }

    /// Load (optionally) from `--config`, then apply CLI overrides.
    pub fn resolve(args: &Args) -> Result<Self> {
        let mut cfg = match args.get("config") {
            Some(path) => Self::load(Path::new(path))?,
            None => Self::default(),
        };
        if let Some(addr) = args.get("addr") {
            cfg.addr = addr.to_string();
        }
        if let Some(speed) = args.get("speed-mbps") {
            cfg.speed_mbps = Some(speed.parse()?);
        }
        if let Some(w) = args.get("workers") {
            cfg.workers = w.parse()?;
        }
        if let Some(s) = args.get("schedule") {
            cfg.schedule = Schedule::parse(s, K)?;
        }
        if let Some(models) = args.get("preload") {
            cfg.preload = models
                .split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
        }
        if let Some(n) = args.get("max-conns") {
            cfg.max_conns = Some(n.parse()?);
        }
        if let Some(p) = args.get("shed-policy") {
            cfg.shed_policy = ShedPolicy::parse(p)?;
        }
        if let Some(s) = args.get("log-interval") {
            cfg.log_interval_s = s.parse()?;
        }
        if let Some(t) = args.get("threads") {
            cfg.threads = Some(t.parse()?);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Args {
        Args::parse(list.iter().map(|s| s.to_string()), &[]).unwrap()
    }

    #[test]
    fn defaults() {
        let cfg = ServeFileConfig::resolve(&args(&[])).unwrap();
        assert_eq!(cfg, ServeFileConfig::default());
    }

    #[test]
    fn file_then_cli_precedence() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("prognet-cfg-{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"addr": "0.0.0.0:9000", "speed_mbps": 0.5,
                "schedule": [4,4,4,4], "preload": ["cnn", "mlp"]}"#,
        )
        .unwrap();
        let cfg = ServeFileConfig::resolve(&args(&[
            "--config",
            path.to_str().unwrap(),
            "--speed-mbps",
            "2.0",
        ]))
        .unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:9000"); // from file
        assert_eq!(cfg.speed_mbps, Some(2.0)); // CLI wins
        assert_eq!(cfg.schedule.stages(), 4);
        assert_eq!(cfg.preload, vec!["cnn", "mlp"]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn threads_key_and_cli_override() {
        let j = Json::parse(r#"{"threads": 4}"#).unwrap();
        assert_eq!(ServeFileConfig::from_json(&j).unwrap().threads, Some(4));
        let cfg = ServeFileConfig::resolve(&args(&["--threads", "0"])).unwrap();
        assert_eq!(cfg.threads, Some(0)); // 0 = auto, still explicit
        assert_eq!(ServeFileConfig::default().threads, None);
    }

    #[test]
    fn fleet_keys_parse_with_cli_override() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("prognet-cfg-fleet-{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"max_conns": 256, "shed_policy": "queue:500", "log_interval_s": 5}"#,
        )
        .unwrap();
        let cfg = ServeFileConfig::resolve(&args(&[
            "--config",
            path.to_str().unwrap(),
            "--shed-policy",
            "degrade:3",
        ]))
        .unwrap();
        assert_eq!(cfg.max_conns, Some(256)); // from file
        assert_eq!(cfg.shed_policy, ShedPolicy::Degrade { max_stages: 3 }); // CLI wins
        assert_eq!(cfg.log_interval_s, 5);
        std::fs::remove_file(&path).ok();
        // bad policy strings fail at startup
        assert!(ServeFileConfig::resolve(&args(&["--shed-policy", "nope"])).is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"addres": "typo"}"#).unwrap();
        assert!(ServeFileConfig::from_json(&j).is_err());
    }

    #[test]
    fn bad_schedule_rejected() {
        let j = Json::parse(r#"{"schedule": [3, 3]}"#).unwrap();
        assert!(ServeFileConfig::from_json(&j).is_err());
    }

    #[test]
    fn null_speed_is_unshaped() {
        let j = Json::parse(r#"{"speed_mbps": null}"#).unwrap();
        assert_eq!(ServeFileConfig::from_json(&j).unwrap().speed_mbps, None);
    }
}
