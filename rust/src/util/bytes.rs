//! Little-endian byte codecs for binary artifacts and the wire protocol.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// Reinterpret a little-endian byte buffer as `f32`s.
pub fn f32_from_le(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("byte length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Reinterpret a little-endian byte buffer as `i32`s.
pub fn i32_from_le(bytes: &[u8]) -> Result<Vec<i32>> {
    if bytes.len() % 4 != 0 {
        bail!("byte length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Reinterpret a little-endian byte buffer as `u32`s.
pub fn u32_from_le(bytes: &[u8]) -> Result<Vec<u32>> {
    if bytes.len() % 4 != 0 {
        bail!("byte length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Serialize `f32`s to little-endian bytes.
pub fn f32_to_le(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Read a whole binary file of f32s.
pub fn read_f32_file(path: &std::path::Path) -> Result<Vec<f32>> {
    f32_from_le(&std::fs::read(path)?)
}

/// Read a whole binary file of i32s.
pub fn read_i32_file(path: &std::path::Path) -> Result<Vec<i32>> {
    i32_from_le(&std::fs::read(path)?)
}

/// Incremental little-endian writer for framed protocols.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }

    /// Length-prefixed (u32) string.
    pub fn str_lp(&mut self, v: &str) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v.as_bytes());
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor-style little-endian reader with bounds checking.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "buffer underrun: need {n} bytes at {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn str_lp(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let bytes = f32_to_le(&vals);
        assert_eq!(f32_from_le(&bytes).unwrap(), vals);
    }

    #[test]
    fn misaligned_rejected() {
        assert!(f32_from_le(&[0, 1, 2]).is_err());
        assert!(u32_from_le(&[0; 7]).is_err());
    }

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = ByteWriter::new();
        w.u8(7).u16(513).u32(70000).u64(1 << 40).f32(2.5).str_lp("héllo");
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 513);
        assert_eq!(r.u32().unwrap(), 70000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 2.5);
        assert_eq!(r.str_lp().unwrap(), "héllo");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn underrun_is_error() {
        let mut r = ByteReader::new(&[1, 2]);
        assert!(r.u32().is_err());
        let mut r2 = ByteReader::new(&[3, 0, 0, 0, b'a']);
        assert!(r2.str_lp().is_err()); // claims 3 bytes, has 1
    }
}
