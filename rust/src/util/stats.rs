//! Descriptive statistics for benchmark reporting (means, percentiles,
//! confidence intervals, throughput helpers).

/// Online + batch summary over f64 samples.

#![forbid(unsafe_code)]
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: &[f64]) -> Self {
        Self {
            samples: samples.to_vec(),
        }
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&sorted, p)
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Half-width of the 95% CI on the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        1.96 * self.std() / (self.samples.len() as f64).sqrt()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Percentile `p` (0–100) of already **sorted** samples via linear
/// interpolation — the one shared implementation behind
/// [`Summary::percentile`] (and through it the fleet SLO quantile
/// blocks). Empty input is `NaN`; a single sample is every percentile of
/// itself.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Quantile `q` (0–1) of a bucketed distribution: walk `buckets`
/// (`bounds.len() + 1` entries, the last catching overflow) to the
/// target rank and report that bucket's upper bound, with `max` standing
/// in for the unbounded overflow bucket. Empty (`count == 0`) is `0.0`.
/// The shared implementation behind
/// [`Histogram::quantile`](crate::metrics::Histogram::quantile).
pub fn bucket_quantile(buckets: &[u64], bounds: &[f64], count: u64, max: f64, q: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let target = (q * count as f64).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in buckets.iter().enumerate() {
        seen += c;
        if seen >= target {
            return bounds.get(i).copied().unwrap_or(max);
        }
    }
    max
}

/// Format seconds human-readably (paper tables use whole seconds).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.1}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Format bytes (MB as in the paper's Size column).
pub fn fmt_bytes(b: u64) -> String {
    const MB: f64 = 1024.0 * 1024.0;
    let bf = b as f64;
    if bf >= MB {
        format!("{:.1} MB", bf / MB)
    } else if bf >= 1024.0 {
        format!("{:.1} KB", bf / 1024.0)
    } else {
        format!("{b} B")
    }
}

/// Relative change `(new - base) / base` as a percent string like "+21%".
pub fn fmt_delta_pct(base: f64, new: f64) -> String {
    if base == 0.0 {
        return "n/a".into();
    }
    let pct = (new - base) / base * 100.0;
    format!("{pct:+.0}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from_samples(&(1..=100).map(|x| x as f64).collect::<Vec<_>>());
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.05);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let few = Summary::from_samples(&[1.0, 2.0, 3.0]);
        let many = Summary::from_samples(&(0..300).map(|i| (i % 3) as f64 + 1.0).collect::<Vec<_>>());
        assert!(many.ci95() < few.ci95());
    }

    #[test]
    fn shared_percentile_pins_known_distribution() {
        let sorted: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&sorted, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile_sorted(&sorted, 95.0) - 95.05).abs() < 1e-9);
        assert!((percentile_sorted(&sorted, 99.0) - 99.01).abs() < 1e-9);
        // n = 1: every percentile is the sample itself
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile_sorted(&[7.5], p), 7.5);
        }
        // empty: NaN, matching Summary::percentile on no samples
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn bucket_quantile_walks_bounds() {
        // 10 samples at ≤1.0, 90 at ≤2.0, empty overflow bucket
        let buckets = [10u64, 90, 0];
        let bounds = [1.0, 2.0];
        assert_eq!(bucket_quantile(&buckets, &bounds, 100, 1.7, 0.05), 1.0);
        assert_eq!(bucket_quantile(&buckets, &bounds, 100, 1.7, 0.5), 2.0);
        assert_eq!(bucket_quantile(&buckets, &bounds, 100, 1.7, 0.99), 2.0);
        // the overflow bucket reports the observed max
        assert_eq!(bucket_quantile(&[0, 0, 3], &bounds, 3, 9.9, 0.5), 9.9);
        // n = 1 and empty edge cases
        assert_eq!(bucket_quantile(&[1, 0, 0], &bounds, 1, 0.4, 0.5), 1.0);
        assert_eq!(bucket_quantile(&[0, 0, 0], &bounds, 0, 0.0, 0.5), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(12.0), "12.0s");
        assert_eq!(fmt_secs(0.5), "500.0ms");
        assert_eq!(fmt_bytes(7 * 1024 * 1024), "7.0 MB");
        assert_eq!(fmt_delta_pct(10.0, 12.0), "+20%");
        assert_eq!(fmt_delta_pct(10.0, 10.0), "+0%");
    }
}
