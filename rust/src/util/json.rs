//! Minimal JSON parser + writer (RFC 8259 subset, enough for manifests).
//!
//! Numbers are parsed as `f64`; integer accessors check exactness.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Load and parse a JSON file.
    pub fn load(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 2f64.powi(53) {
            bail!("not an exact integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        usize::try_from(n).map_err(|_| anyhow!("negative index {n}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(anyhow!("expected object")),
        }
    }

    /// Object field accessor with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    /// Optional field accessor.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building JSON documents.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy raw bytes of this code point.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number '{text}' at byte {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_i64().unwrap(), 42);
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(Json::parse("\"hi\"").unwrap().as_str().unwrap(), "hi");
        assert!(Json::parse("true").unwrap().as_bool().unwrap());
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s\n"],"n":null,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""aA\n\t\\b""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "aA\n\t\\b");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo→");
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn exact_int_check() {
        assert!(Json::parse("1.5").unwrap().as_i64().is_err());
        assert!(Json::parse("-3").unwrap().as_usize().is_err());
    }
}
