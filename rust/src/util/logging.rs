//! Minimal leveled logger writing to stderr, controlled by `PROGNET_LOG`
//! (`error|warn|info|debug|trace`, default `info`). An unrecognized
//! value warns once and falls back to `info` rather than silently
//! defaulting. Timestamps go through the injectable
//! [`Clock`](crate::util::sync::Clock) ([`set_clock`]), so tests and the
//! model checker see deterministic log times.

#![forbid(unsafe_code)]

use crate::util::sync::atomic::{AtomicU8, Ordering};
use crate::util::sync::{Clock, Mutex, OnceLock};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
/// Timestamp base: the clock log lines read and the epoch they are
/// relative to. Installed lazily (real clock) or via [`set_clock`].
static TIME: OnceLock<Mutex<(Clock, Instant)>> = OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Parse a `PROGNET_LOG` value: `(level, recognized)`. Unset (`None`)
/// is the silent default; an unrecognized string is `info` + a warning.
fn parse_level(value: Option<&str>) -> (u8, bool) {
    match value {
        None => (2, true),
        Some("error") => (0, true),
        Some("warn") => (1, true),
        Some("info") => (2, true),
        Some("debug") => (3, true),
        Some("trace") => (4, true),
        Some(_) => (2, false),
    }
}

fn level() -> u8 {
    // Relaxed is deliberate: LEVEL caches an idempotent parse of an env
    // var, so the worst a stale read costs is one redundant re-parse —
    // there is no data published alongside the flag to order against.
    let v = LEVEL.load(Ordering::Relaxed); // lint:allow ordering-relaxed-shared
    if v != 255 {
        return v;
    }
    let raw = std::env::var("PROGNET_LOG").ok();
    let (parsed, recognized) = parse_level(raw.as_deref());
    // store before warning: the warning routes through `log` → `enabled`
    // → `level`, which must hit the cached value, not re-enter the parse
    LEVEL.store(parsed, Ordering::Relaxed); // lint:allow ordering-relaxed-shared
    if !recognized {
        log(
            Level::Warn,
            module_path!(),
            &format!(
                "unrecognized PROGNET_LOG value '{}' (expected \
                 error|warn|info|debug|trace); using info",
                raw.unwrap_or_default()
            ),
        );
    }
    parsed
}

/// Force a level programmatically (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed); // lint:allow ordering-relaxed-shared
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

fn time_cell() -> &'static Mutex<(Clock, Instant)> {
    TIME.get_or_init(|| {
        let clock = Clock::real();
        let epoch = clock.now();
        Mutex::new((clock, epoch))
    })
}

/// Route log timestamps through `clock`, re-based to its current
/// instant: lines logged from now on show seconds on that clock —
/// virtual time when the clock is manual.
pub fn set_clock(clock: Clock) {
    let epoch = clock.now();
    *time_cell().lock().unwrap() = (clock, epoch);
}

/// Seconds since the logger's epoch on the installed clock.
fn timestamp() -> f64 {
    let t = time_cell().lock().unwrap();
    t.0.now().saturating_duration_since(t.1).as_secs_f64()
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let secs = timestamp();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{secs:9.3}] {tag} {module}: {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn every_documented_level_parses() {
        assert_eq!(parse_level(Some("error")), (0, true));
        assert_eq!(parse_level(Some("warn")), (1, true));
        assert_eq!(parse_level(Some("info")), (2, true));
        assert_eq!(parse_level(Some("debug")), (3, true));
        assert_eq!(parse_level(Some("trace")), (4, true));
    }

    #[test]
    fn unset_is_a_silent_info_default() {
        assert_eq!(parse_level(None), (2, true));
    }

    #[test]
    fn unrecognized_values_fall_back_to_info_with_a_warning() {
        assert_eq!(parse_level(Some("INFO")), (2, false));
        assert_eq!(parse_level(Some("verbose")), (2, false));
        assert_eq!(parse_level(Some("")), (2, false));
    }

    #[test]
    fn manual_clock_drives_timestamps() {
        let c = Clock::manual();
        set_clock(c.clone());
        assert_eq!(timestamp(), 0.0);
        c.advance(std::time::Duration::from_millis(1500));
        assert!((timestamp() - 1.5).abs() < 1e-9);
    }
}
