//! Minimal leveled logger writing to stderr, controlled by `PROGNET_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

#![forbid(unsafe_code)]

use crate::util::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

fn level() -> u8 {
    // Relaxed is deliberate: LEVEL caches an idempotent parse of an env
    // var, so the worst a stale read costs is one redundant re-parse —
    // there is no data published alongside the flag to order against.
    let v = LEVEL.load(Ordering::Relaxed); // lint:allow ordering-relaxed-shared
    if v != 255 {
        return v;
    }
    let parsed = match std::env::var("PROGNET_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed); // lint:allow ordering-relaxed-shared
    parsed
}

/// Force a level programmatically (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed); // lint:allow ordering-relaxed-shared
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{secs:9.3}] {tag} {module}: {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
