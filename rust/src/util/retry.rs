//! Budgeted retry: deadline-capped exponential backoff with
//! deterministic jitter.
//!
//! Every reconnect/refill path in the crate (session resume, edge
//! origin fills and tail relays, router failover dials, load-generator
//! connects) shares this one policy type instead of hand-rolled
//! `sleep(20ms * attempt)` loops, so retry budgets are visible in one
//! place and chaos tests can assert the exact schedule. The
//! `raw-retry-loop` lint rule (see `prognet-lint`) flags ad-hoc retry
//! loops in protocol modules to keep it that way.
//!
//! Jitter is deterministic: a [`crate::util::rng::Rng`] seeded from the
//! policy (optionally mixed with a per-call salt) decides each delay, so
//! a fixed seed reproduces the same backoff sequence — chaos runs stay
//! replayable. Sleeps go through the injectable
//! [`Clock`](crate::util::sync::Clock), so virtual-time tests retry
//! without blocking and the `wall-clock-in-protocol` invariant holds at
//! the call sites.

#![forbid(unsafe_code)]

use crate::util::rng::Rng;
use crate::util::sync::Clock;
use std::time::Duration;

/// Backoff/budget parameters. Construct with [`RetryPolicy::new`] and
/// shape with the builder methods; [`RetryPolicy::start`] yields the
/// stateful [`Retry`] that tracks attempts and the deadline.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Maximum attempts in total (first try included). 1 = no retries.
    max_attempts: u32,
    /// Delay before the first retry.
    base_delay: Duration,
    /// Multiplier applied per subsequent retry.
    factor: f64,
    /// Per-sleep cap.
    max_delay: Duration,
    /// Total budget across all sleeps measured from `start()`; a retry
    /// whose sleep would land past the deadline is refused instead.
    budget: Option<Duration>,
    /// Fraction of each delay that is randomized away, in `[0, 1]`:
    /// the jittered delay is uniform in `[(1-jitter)*d, d]`.
    jitter: f64,
    /// Seed for the deterministic jitter stream.
    seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_delay: Duration::from_millis(20),
            factor: 2.0,
            max_delay: Duration::from_secs(1),
            budget: None,
            jitter: 0.5,
            seed: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl RetryPolicy {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total attempts allowed (clamped to ≥ 1).
    pub fn attempts(mut self, n: u32) -> Self {
        self.max_attempts = n.max(1);
        self
    }

    pub fn base_delay(mut self, d: Duration) -> Self {
        self.base_delay = d;
        self
    }

    pub fn factor(mut self, f: f64) -> Self {
        self.factor = if f.is_finite() && f >= 1.0 { f } else { 1.0 };
        self
    }

    pub fn max_delay(mut self, d: Duration) -> Self {
        self.max_delay = d;
        self
    }

    /// Deadline across the whole retry sequence, measured from
    /// [`RetryPolicy::start`].
    pub fn budget(mut self, d: Duration) -> Self {
        self.budget = Some(d);
        self
    }

    pub fn jitter(mut self, j: f64) -> Self {
        self.jitter = j.clamp(0.0, 1.0);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn max_attempts(&self) -> u32 {
        self.max_attempts
    }

    /// Begin a retry sequence on `clock`. `salt` decorrelates jitter
    /// between concurrent sequences sharing one policy (hash of a
    /// connection id, client index, …); pass 0 when there is only one.
    pub fn start(&self, clock: Clock, salt: u64) -> Retry {
        Retry {
            rng: Rng::new(self.seed ^ salt),
            started: clock.now(),
            clock,
            policy: self.clone(),
            retries_done: 0,
        }
    }

    /// The deterministic backoff schedule this policy would produce for
    /// `salt` — what tests assert against without sleeping.
    pub fn preview(&self, salt: u64) -> Vec<Duration> {
        let clock = Clock::manual();
        let mut retry = self.start(clock, salt);
        let mut delays = Vec::new();
        while let Some(d) = retry.backoff() {
            delays.push(d);
        }
        delays
    }
}

/// One in-flight retry sequence: owns the attempt counter, the jitter
/// stream and the deadline. Obtained from [`RetryPolicy::start`].
#[derive(Debug)]
pub struct Retry {
    policy: RetryPolicy,
    clock: Clock,
    rng: Rng,
    started: std::time::Instant,
    retries_done: u32,
}

impl Retry {
    /// Retries consumed so far.
    pub fn retries_done(&self) -> u32 {
        self.retries_done
    }

    /// The attempt number (1-based) the caller is about to make.
    pub fn attempt(&self) -> u32 {
        self.retries_done + 1
    }

    /// Whether another retry is currently permitted by the attempt cap
    /// (the budget is only checked once the delay is known).
    pub fn can_retry(&self) -> bool {
        self.retries_done + 1 < self.policy.max_attempts
    }

    /// Sleep out the next backoff and return the delay slept, or `None`
    /// when the attempt cap is spent or the sleep would overrun the
    /// budget (the sequence is then over — fail closed).
    pub fn backoff(&mut self) -> Option<Duration> {
        if !self.can_retry() {
            return None;
        }
        let exp = self
            .policy
            .base_delay
            .as_secs_f64()
            .max(0.0)
            .mul_add(self.policy.factor.powi(self.retries_done as i32), 0.0);
        let capped = exp.min(self.policy.max_delay.as_secs_f64());
        let scale = 1.0 - self.policy.jitter * self.rng.f64();
        let delay = Duration::from_secs_f64(capped * scale);
        if let Some(budget) = self.policy.budget {
            let elapsed = self.clock.now().saturating_duration_since(self.started);
            if elapsed + delay > budget {
                return None;
            }
        }
        self.retries_done += 1;
        self.clock.sleep(delay);
        Some(delay)
    }

    /// Run `op` under this sequence: call it with the 1-based attempt
    /// number, retrying on `Err` until the policy refuses. Returns the
    /// first `Ok` or the last error.
    pub fn run<T, E>(&mut self, mut op: impl FnMut(u32) -> Result<T, E>) -> Result<T, E> {
        loop {
            match op(self.attempt()) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if self.backoff().is_none() {
                        return Err(e);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetryPolicy {
        RetryPolicy::new()
            .attempts(4)
            .base_delay(Duration::from_millis(100))
            .factor(2.0)
            .max_delay(Duration::from_secs(10))
            .jitter(0.5)
            .seed(42)
    }

    #[test]
    fn schedule_is_deterministic_and_bounded() {
        let p = policy();
        let a = p.preview(7);
        let b = p.preview(7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3); // 4 attempts → 3 backoffs
        for (i, d) in a.iter().enumerate() {
            let full = Duration::from_millis(100 * (1 << i as u32));
            assert!(*d <= full, "delay {i} {d:?} above cap {full:?}");
            assert!(
                d.as_secs_f64() >= full.as_secs_f64() * 0.5 - 1e-9,
                "delay {i} {d:?} below jitter floor"
            );
        }
    }

    #[test]
    fn salts_decorrelate_jitter() {
        let p = policy();
        assert_ne!(p.preview(1), p.preview(2));
    }

    #[test]
    fn budget_refuses_overrunning_sleep() {
        // budget below the first backoff floor (≥ 50ms at jitter 0.5)
        let p = policy().budget(Duration::from_millis(10));
        assert!(p.preview(0).is_empty());
        // generous budget admits the whole schedule
        let p = policy().budget(Duration::from_secs(60));
        assert_eq!(p.preview(0).len(), 3);
    }

    #[test]
    fn budget_is_cumulative_across_sleeps() {
        // floor of the 3-delay schedule is 100+200+400 halves = 350ms;
        // a 250ms budget must cut the sequence short.
        let p = policy().budget(Duration::from_millis(250));
        let delays = p.preview(0);
        assert!(delays.len() < 3, "expected truncation, got {delays:?}");
        let total: Duration = delays.iter().sum();
        assert!(total <= Duration::from_millis(250));
    }

    #[test]
    fn zero_jitter_is_pure_exponential() {
        let p = policy().jitter(0.0).attempts(3);
        assert_eq!(
            p.preview(0),
            vec![Duration::from_millis(100), Duration::from_millis(200)]
        );
    }

    #[test]
    fn max_delay_caps_growth() {
        let p = policy().jitter(0.0).max_delay(Duration::from_millis(150));
        assert_eq!(
            p.preview(0),
            vec![
                Duration::from_millis(100),
                Duration::from_millis(150),
                Duration::from_millis(150)
            ]
        );
    }

    #[test]
    fn run_retries_then_succeeds() {
        let clock = Clock::manual();
        let t0 = clock.now();
        let mut retry = policy().start(clock.clone(), 0);
        let mut calls = 0u32;
        let out: Result<u32, &str> = retry.run(|attempt| {
            calls += 1;
            assert_eq!(attempt, calls);
            if attempt < 3 {
                Err("not yet")
            } else {
                Ok(attempt)
            }
        });
        assert_eq!(out, Ok(3));
        assert_eq!(calls, 3);
        assert_eq!(retry.retries_done(), 2);
        // manual clock advanced by exactly the two backoffs
        assert!(clock.now() > t0);
    }

    #[test]
    fn run_returns_last_error_when_exhausted() {
        let clock = Clock::manual();
        let mut retry = policy().start(clock, 0);
        let mut calls = 0u32;
        let out: Result<(), u32> = retry.run(|_| {
            calls += 1;
            Err(calls)
        });
        assert_eq!(out, Err(4)); // 4 attempts, last error surfaces
    }

    #[test]
    fn single_attempt_never_sleeps() {
        let p = RetryPolicy::new().attempts(1);
        assert!(p.preview(0).is_empty());
    }
}
