//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: options map + positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    /// `known_flags` lists boolean options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        bail!("option --{body} requires a value");
                    }
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    bail!("option --{body} requires a value");
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments after the subcommand.
    pub fn from_env(skip: usize, known_flags: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(skip), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string())
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn options_and_flags() {
        let a = parse(
            &["--model", "cnn", "--speed=1.5", "--verbose", "pos1"],
            &["verbose"],
        );
        assert_eq!(a.get("model"), Some("cnn"));
        assert_eq!(a.get_f64("speed", 0.0).unwrap(), 1.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("other"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_and_require() {
        let a = parse(&["--x", "3"], &[]);
        assert_eq!(a.get_usize("x", 0).unwrap(), 3);
        assert_eq!(a.get_usize("y", 7).unwrap(), 7);
        assert_eq!(a.get_or("z", "d"), "d");
        assert!(a.require("w").is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["--k".to_string()].into_iter(), &[]).is_err());
        assert!(Args::parse(
            ["--a".to_string(), "--b".to_string(), "v".to_string()].into_iter(),
            &[]
        )
        .is_err());
    }

    #[test]
    fn lists_and_terminator() {
        let a = parse(&["--models", "a,b,c", "--", "--raw"], &[]);
        assert_eq!(a.get_list("models", &[]), vec!["a", "b", "c"]);
        assert_eq!(a.get_list("none", &["x"]), vec!["x"]);
        assert_eq!(a.positional(), &["--raw".to_string()]);
    }

    #[test]
    fn bad_number() {
        let a = parse(&["--n", "abc"], &[]);
        assert!(a.get_usize("n", 0).is_err());
    }
}
