//! Deterministic PRNGs (SplitMix64 seeding + xoshiro256++) and the
//! distributions the simulators need. The vendored set has no `rand`
//! crate; these match the published reference implementations.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.

#![forbid(unsafe_code)]
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (unbiased via rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let v = self.f64();
            if v > 1e-300 {
                break v;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let v = self.f64();
            if v > 1e-300 {
                break v;
            }
        };
        -u.ln() / lambda
    }

    /// Fill a slice with normal f32s (for synthetic workload tensors).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(3);
        let w = [0.0, 0.0, 10.0, 0.1];
        let picks: Vec<usize> = (0..200).map(|_| r.weighted(&w)).collect();
        assert!(picks.iter().filter(|&&i| i == 2).count() > 180);
        assert!(!picks.contains(&0));
    }
}
