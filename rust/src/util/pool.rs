//! Fixed-size thread pool, a bounded MPMC channel, and a scratch-buffer
//! pool, all built on std.
//!
//! [`BoundedQueue`] is the backpressure primitive between pipeline
//! stages (session event streams, the concurrent-mode wire queue).
//! [`BufferPool`] recycles large scratch allocations on compute hot
//! paths (the reference runtime's activation ping-pong and im2col
//! buffers). [`ThreadPool`] powered the server's historical
//! thread-per-connection loop; since the fleet PR the server is a
//! sharded reactor (`fleet::reactor`) with no per-connection threads, so
//! the pool is retained only as a general-purpose utility for
//! batch-style callers.

#![forbid(unsafe_code)]

use std::collections::VecDeque;
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    active: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// A fixed-size worker pool. Jobs are FIFO; `wait_idle` blocks until the
/// queue is drained and all workers are parked.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("prognet-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.cv.notify_one();
    }

    /// Block until no queued or running jobs remain.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_mx.lock().unwrap();
        loop {
            let queued = self.shared.queue.lock().unwrap().len();
            let active = self.shared.active.load(Ordering::SeqCst);
            if queued == 0 && active == 0 {
                return;
            }
            let (g, _) = self
                .shared
                .done_cv
                .wait_timeout(guard, std::time::Duration::from_millis(20))
                .unwrap();
            guard = g;
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        sh.active.fetch_add(1, Ordering::SeqCst);
        job();
        sh.active.fetch_sub(1, Ordering::SeqCst);
        sh.done_cv.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A recycling pool of `Vec<T>` scratch buffers.
///
/// Concurrency-safe and cheap: [`BufferPool::take`] hands out a buffer
/// resized to the requested length (contents unspecified — callers
/// overwrite), [`BufferPool::put`] returns it for reuse. Bounds how many
/// idle buffers it retains so a one-off huge batch doesn't pin memory
/// forever.
pub struct BufferPool<T> {
    free: Mutex<Vec<Vec<T>>>,
    max_idle: usize,
}

impl<T: Copy + Default> BufferPool<T> {
    /// A pool retaining at most `max_idle` idle buffers.
    pub fn new(max_idle: usize) -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            max_idle,
        }
    }

    /// A buffer with `len()` == `len`; contents are unspecified (reused
    /// buffers keep stale data — always overwrite before reading).
    pub fn take(&self, len: usize) -> Vec<T> {
        let mut buf = self.free.lock().unwrap().pop().unwrap_or_default();
        if buf.len() < len {
            buf.resize(len, T::default());
        } else {
            buf.truncate(len);
        }
        buf
    }

    /// Return a buffer for reuse (dropped if the pool is full).
    pub fn put(&self, buf: Vec<T>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_idle {
            free.push(buf);
        }
    }

    /// Idle buffers currently retained.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

impl<T: Copy + Default> Default for BufferPool<T> {
    /// A pool sized for a handful of concurrent workers.
    fn default() -> Self {
        Self::new(16)
    }
}

/// A bounded multi-producer multi-consumer channel (blocking send/recv)
/// used for backpressure between pipeline stages.
pub struct BoundedQueue<T> {
    inner: Arc<QueueInner<T>>,
}

struct QueueInner<T> {
    buf: Mutex<VecDeque<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
    closed: AtomicBool,
}

impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        Self {
            inner: self.inner.clone(),
        }
    }
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            inner: Arc::new(QueueInner {
                buf: Mutex::new(VecDeque::new()),
                cap,
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                closed: AtomicBool::new(false),
            }),
        }
    }

    /// Blocking push; returns `false` if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut buf = self.inner.buf.lock().unwrap();
        loop {
            if self.inner.closed.load(Ordering::SeqCst) {
                return false;
            }
            if buf.len() < self.inner.cap {
                buf.push_back(item);
                self.inner.not_empty.notify_one();
                return true;
            }
            buf = self.inner.not_full.wait(buf).unwrap();
        }
    }

    /// Blocking pop; `None` when closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut buf = self.inner.buf.lock().unwrap();
        loop {
            if let Some(v) = buf.pop_front() {
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if self.inner.closed.load(Ordering::SeqCst) {
                return None;
            }
            buf = self.inner.not_empty.wait(buf).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut buf = self.inner.buf.lock().unwrap();
        let v = buf.pop_front();
        if v.is_some() {
            self.inner.not_full.notify_one();
        }
        v
    }

    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.buf.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = counter.clone();
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn buffer_pool_recycles_and_caps() {
        let pool: BufferPool<f32> = BufferPool::new(2);
        let a = pool.take(100);
        assert_eq!(a.len(), 100);
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        // reuse shrinks/grows to the requested length
        let b = pool.take(10);
        assert_eq!(b.len(), 10);
        assert_eq!(pool.idle(), 0);
        let c = pool.take(1000);
        assert_eq!(c.len(), 1000);
        pool.put(b);
        pool.put(c);
        assert_eq!(pool.idle(), 2);
        // over the idle cap: dropped, not retained
        pool.put(vec![0.0; 4]);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn queue_fifo_and_close() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
        assert!(!q.push(3));
    }

    #[test]
    fn queue_backpressure() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        q.push(1);
        q.push(2);
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            // blocks until the consumer pops
            q2.push(3);
            true
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn queue_multi_consumer_conservation() {
        let q: BoundedQueue<u64> = BoundedQueue::new(8);
        let sum = Arc::new(AtomicU64::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let s = sum.clone();
                std::thread::spawn(move || {
                    while let Some(v) = q.pop() {
                        s.fetch_add(v, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        let mut expect = 0;
        for i in 1..=200u64 {
            expect += i;
            q.push(i);
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(sum.load(Ordering::SeqCst), expect);
    }
}
