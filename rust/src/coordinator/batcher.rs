//! Dynamic batching: requests accumulate up to `max_batch` or `max_delay`,
//! whichever first, then run as one executable call.
//!
//! A batcher binds to an [`ApproxModel`], not a finished session: every
//! batch snapshots the newest published weights at formation time, so a
//! model that is still downloading serves requests with whatever
//! approximation has arrived and upgrades transparently (§III-C).

#![forbid(unsafe_code)]

use crate::util::sync::mpsc;
use crate::util::sync::clock;
use crate::util::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::state::WeightStore;
use crate::metrics::Histogram;
use crate::runtime::{ApproxModel, ModelSession};
use crate::util::pool::BoundedQueue;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_delay: Duration::from_millis(5),
            queue_cap: 1024,
        }
    }
}

/// Reply to one inference request.
#[derive(Debug)]
pub struct InferReply {
    /// output row (output_dim values)
    pub output: Result<Vec<f32>>,
    /// weights version/bits used
    pub cum_bits: u32,
    /// publish counter of the weight snapshot used
    pub version: u64,
    /// queueing + execution latency
    pub latency: Duration,
}

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::Sender<InferReply>,
}

/// A per-model dynamic batcher with its own worker thread.
pub struct Batcher {
    queue: BoundedQueue<Request>,
    worker: Option<JoinHandle<()>>,
    input_numel: usize,
    stats: Arc<crate::util::sync::Mutex<Histogram>>,
}

impl Batcher {
    /// Spawn the batcher worker bound to a hot-swappable model. Each
    /// batch uses the freshest published snapshot at formation time, so
    /// the lane serves mid-download and upgrades as stages land.
    pub fn bind(model: ApproxModel, config: BatcherConfig) -> Self {
        let queue: BoundedQueue<Request> = BoundedQueue::new(config.queue_cap);
        let q = queue.clone();
        let input_numel = model.manifest().input_numel();
        let stats = Arc::new(crate::util::sync::Mutex::new(Histogram::new()));
        let stats2 = stats.clone();
        let worker = std::thread::Builder::new()
            .name(format!("batcher-{}", model.manifest().name))
            .spawn(move || {
                batch_loop(q, model, config, stats2);
            })
            .expect("spawn batcher");
        Self {
            queue,
            worker: Some(worker),
            input_numel,
            stats,
        }
    }

    /// Convenience: bind a finished session plus a standalone
    /// [`WeightStore`] (the pre-`ApproxModel` calling convention).
    pub fn start(session: Arc<ModelSession>, weights: WeightStore, config: BatcherConfig) -> Self {
        Self::bind(weights.bind(session), config)
    }

    /// Enqueue one request; the reply arrives on the returned receiver.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<InferReply>> {
        anyhow::ensure!(
            image.len() == self.input_numel,
            "image has {} values, expected {}",
            image.len(),
            self.input_numel
        );
        let (tx, rx) = mpsc::channel();
        let ok = self.queue.push(Request {
            image,
            enqueued: clock::now(),
            reply: tx,
        });
        anyhow::ensure!(ok, "batcher is shut down");
        Ok(rx)
    }

    /// Blocking convenience call.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<InferReply> {
        let rx = self.submit(image)?;
        Ok(rx.recv()?)
    }

    /// Latency histogram snapshot.
    pub fn latency_stats(&self) -> Histogram {
        self.stats.lock().unwrap().clone()
    }

    pub fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn batch_loop(
    queue: BoundedQueue<Request>,
    model: ApproxModel,
    config: BatcherConfig,
    stats: Arc<crate::util::sync::Mutex<Histogram>>,
) {
    let session = model.session().clone();
    let input_numel = session.manifest().input_numel();
    // whole batches go to the backend as one execute; the image panel is
    // preallocated once and reused — no per-batch allocation churn
    let mut images: Vec<f32> = Vec::with_capacity(config.max_batch * input_numel);
    let mut batch: Vec<Request> = Vec::with_capacity(config.max_batch);
    loop {
        // Block for the first request of the batch.
        let Some(first) = queue.pop() else { break };
        let deadline = clock::now() + config.max_delay;
        batch.clear();
        batch.push(first);
        while batch.len() < config.max_batch {
            match queue.try_pop() {
                Some(r) => batch.push(r),
                None => {
                    if clock::now() >= deadline {
                        break;
                    }
                    clock::sleep(Duration::from_micros(200));
                }
            }
        }

        let snap = model.snapshot();
        let n = batch.len();
        images.clear();
        for r in batch.iter() {
            images.extend_from_slice(&r.image);
        }
        let result = session.infer(&images, n, &snap.flat);
        match result {
            Ok(out) => {
                for (i, req) in batch.drain(..).enumerate() {
                    let latency = req.enqueued.elapsed();
                    stats.lock().unwrap().record(latency.as_secs_f64());
                    let _ = req.reply.send(InferReply {
                        output: Ok(out.row(i).to_vec()),
                        cum_bits: snap.cum_bits,
                        version: snap.version,
                        latency,
                    });
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch.drain(..) {
                    let latency = req.enqueued.elapsed();
                    let _ = req.reply.send(InferReply {
                        output: Err(anyhow::anyhow!("{msg}")),
                        cum_bits: snap.cum_bits,
                        version: snap.version,
                        latency,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;
    use crate::runtime::Engine;

    fn setup() -> Option<(Arc<ModelSession>, WeightStore)> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let engine = Engine::global().unwrap();
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let session = Arc::new(ModelSession::load_batches(&engine, m, &[1, 32]).unwrap());
        let ws = WeightStore::empty(m.param_count);
        ws.publish(&m.load_weights().unwrap(), 16);
        Some((session, ws))
    }

    #[test]
    fn single_request_roundtrip() {
        let Some((session, ws)) = setup() else { return };
        let numel = session.manifest().input_numel();
        let mut b = Batcher::start(session, ws, BatcherConfig::default());
        let reply = b.infer_blocking(vec![0.5f32; numel]).unwrap();
        let out = reply.output.unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(reply.cum_bits, 16);
        b.shutdown();
    }

    #[test]
    fn many_requests_all_answered_exactly_once() {
        let Some((session, ws)) = setup() else { return };
        let numel = session.manifest().input_numel();
        let b = Batcher::start(
            session,
            ws,
            BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(2),
                queue_cap: 256,
            },
        );
        let rxs: Vec<_> = (0..50)
            .map(|i| b.submit(vec![(i % 7) as f32 * 0.1; numel]).unwrap())
            .collect();
        let mut answered = 0;
        for rx in rxs {
            let reply = rx.recv().unwrap();
            assert!(reply.output.is_ok());
            answered += 1;
            // exactly-once: a second recv must fail (sender dropped)
            assert!(rx.try_recv().is_err());
        }
        assert_eq!(answered, 50);
        assert_eq!(b.latency_stats().count(), 50);
    }

    #[test]
    fn bound_batcher_serves_upgrading_weights() {
        // fixture-backed (runs without artifacts): the batcher answers
        // with whatever snapshot is published, and upgrades in place
        let reg = crate::testutil::fixture::executable_models("batch-bind").unwrap();
        let m = reg.get("dense3").unwrap().clone();
        let engine = Engine::reference();
        let session = Arc::new(ModelSession::load(&engine, &m).unwrap());
        let approx = crate::runtime::ApproxModel::new(session);
        let b = Batcher::bind(approx.clone(), BatcherConfig::default());
        let img = vec![0.5f32; m.input_numel()];
        approx.publish(&vec![0.0; m.param_count], 2);
        let r1 = b.infer_blocking(img.clone()).unwrap();
        assert_eq!(r1.cum_bits, 2);
        assert_eq!(r1.version, 1);
        approx.publish(&m.load_weights().unwrap(), 16);
        let r2 = b.infer_blocking(img).unwrap();
        assert_eq!(r2.cum_bits, 16);
        assert_eq!(r2.version, 2);
        assert_eq!(r2.output.unwrap().len(), m.classes);
    }

    #[test]
    fn wrong_image_size_rejected() {
        let Some((session, ws)) = setup() else { return };
        let b = Batcher::start(session, ws, BatcherConfig::default());
        assert!(b.submit(vec![0.0; 3]).is_err());
    }

    #[test]
    fn batching_outputs_match_unbatched() {
        let Some((session, ws)) = setup() else { return };
        let numel = session.manifest().input_numel();
        let flat = ws.snapshot();
        // direct single inference
        let img = vec![0.25f32; numel];
        let direct = session.infer(&img, 1, &flat.flat).unwrap();
        let b = Batcher::start(session.clone(), ws, BatcherConfig::default());
        // submit a burst so some requests batch together
        let rxs: Vec<_> = (0..16).map(|_| b.submit(img.clone()).unwrap()).collect();
        for rx in rxs {
            let out = rx.recv().unwrap().output.unwrap();
            for (a, c) in out.iter().zip(direct.row(0)) {
                assert!((a - c).abs() < 1e-4);
            }
        }
    }
}
