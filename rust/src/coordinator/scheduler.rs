//! §III-C stage-scheduling policy, plus multi-model stage interleaving.
//!
//! Concurrency makes progressive inference free only while per-stage
//! reconstruct+infer cost fits inside the transfer gap to the next stage.
//! The scheduler tracks an EWMA of both and decides, per completed stage,
//! whether to (a) infer it, (b) skip to the newest stage when lagging, or
//! (c) defer everything to the final stage (degenerate link).
//!
//! [`interleave_stages`] extends the per-stage granularity across models:
//! with the wire protocol's stage-range requests, one connection can
//! deliver stage k of model A between stages of model B, so the planner
//! orders (model, stage) pairs by weighted-fair virtual time.

/// Decision for a newly completed stage.

#![forbid(unsafe_code)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerDecision {
    /// Run inference on this stage.
    Infer,
    /// Skip — a newer stage will arrive before this inference would end.
    Skip,
}

/// Adaptive stage scheduler.
#[derive(Debug, Clone)]
pub struct StageScheduler {
    /// EWMA of reconstruct+infer seconds
    infer_cost: f64,
    /// EWMA of the gap between consecutive stage completions
    stage_gap: f64,
    alpha: f64,
    last_stage_t: Option<f64>,
    /// never skip the final stage
    total_stages: usize,
    /// tunable: infer when cost <= headroom * gap
    headroom: f64,
}

impl StageScheduler {
    pub fn new(total_stages: usize) -> Self {
        Self {
            infer_cost: 0.0,
            stage_gap: f64::INFINITY,
            alpha: 0.4,
            last_stage_t: None,
            total_stages,
            headroom: 1.0,
        }
    }

    pub fn with_headroom(mut self, headroom: f64) -> Self {
        self.headroom = headroom;
        self
    }

    /// Record the observed cost of a reconstruct+infer pass.
    pub fn observe_infer_cost(&mut self, secs: f64) {
        if self.infer_cost == 0.0 {
            self.infer_cost = secs;
        } else {
            self.infer_cost = self.alpha * secs + (1.0 - self.alpha) * self.infer_cost;
        }
    }

    /// A stage completed at time `t`; decide what to do with it.
    pub fn on_stage_complete(&mut self, stage: usize, t: f64) -> SchedulerDecision {
        if let Some(prev) = self.last_stage_t {
            let gap = (t - prev).max(1e-9);
            self.stage_gap = if self.stage_gap.is_finite() {
                self.alpha * gap + (1.0 - self.alpha) * self.stage_gap
            } else {
                gap
            };
        }
        self.last_stage_t = Some(t);

        if stage + 1 == self.total_stages {
            return SchedulerDecision::Infer; // final model always shown
        }
        if self.infer_cost == 0.0 || !self.stage_gap.is_finite() {
            return SchedulerDecision::Infer; // no data yet: be eager
        }
        if self.infer_cost <= self.headroom * self.stage_gap {
            SchedulerDecision::Infer
        } else {
            SchedulerDecision::Skip
        }
    }

    pub fn estimated_infer_cost(&self) -> f64 {
        self.infer_cost
    }

    pub fn estimated_stage_gap(&self) -> f64 {
        if self.stage_gap.is_finite() {
            self.stage_gap
        } else {
            0.0
        }
    }
}

/// One model's stages to schedule onto a shared connection.
#[derive(Debug, Clone)]
pub struct InterleaveModel {
    pub name: String,
    /// absolute index of the first stage to plan (earlier stages are
    /// assumed already delivered, e.g. stage 0 fetched to learn sizes)
    pub first_stage: usize,
    /// wire bytes of each planned stage, starting at `first_stage`
    pub stage_bytes: Vec<u64>,
    /// relative bandwidth share (> 0); 2.0 = twice the share of 1.0
    pub priority: f64,
}

/// One step of an interleaved multi-model delivery plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StagePlanEntry {
    pub model: String,
    /// absolute stage index to request as `stages: stage..stage+1`
    pub stage: usize,
}

/// Weighted-fair interleaving of several models' stages onto one
/// connection. Each model advances through its stages in order; the next
/// entry is always the pending model with the least virtual time
/// (bytes scheduled ÷ priority), so high-priority models reach usable
/// accuracy sooner without starving the rest — per-stage granularity as
/// the scheduling unit, as in SLIDE-style simultaneous downloading.
pub fn interleave_stages(models: &[InterleaveModel]) -> Vec<StagePlanEntry> {
    let mut next = vec![0usize; models.len()];
    let mut vtime = vec![0f64; models.len()];
    let total: usize = models.iter().map(|m| m.stage_bytes.len()).sum();
    let mut plan = Vec::with_capacity(total);
    for _ in 0..total {
        let mut best: Option<usize> = None;
        for (i, m) in models.iter().enumerate() {
            if next[i] >= m.stage_bytes.len() {
                continue;
            }
            if best.is_none_or(|b| vtime[i] < vtime[b]) {
                best = Some(i);
            }
        }
        let Some(i) = best else { break };
        plan.push(StagePlanEntry {
            model: models[i].name.clone(),
            stage: models[i].first_stage + next[i],
        });
        vtime[i] += models[i].stage_bytes[next[i]] as f64 / models[i].priority.max(1e-9);
        next[i] += 1;
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eager_without_observations() {
        let mut s = StageScheduler::new(8);
        assert_eq!(s.on_stage_complete(0, 1.0), SchedulerDecision::Infer);
    }

    #[test]
    fn fast_inference_always_runs() {
        let mut s = StageScheduler::new(8);
        s.observe_infer_cost(0.01);
        for i in 0..8 {
            // stages 1s apart, inference 10ms → always infer
            assert_eq!(
                s.on_stage_complete(i, i as f64),
                SchedulerDecision::Infer,
                "stage {i}"
            );
            s.observe_infer_cost(0.01);
        }
    }

    #[test]
    fn slow_inference_skips_middle_stages() {
        let mut s = StageScheduler::new(8);
        s.observe_infer_cost(5.0); // inference 5s
        let mut decisions = Vec::new();
        for i in 0..8 {
            // stages 0.5s apart
            decisions.push(s.on_stage_complete(i, i as f64 * 0.5));
            s.observe_infer_cost(5.0);
        }
        // must skip some interior stages…
        assert!(decisions[1..7].contains(&SchedulerDecision::Skip));
        // …but never the final one
        assert_eq!(decisions[7], SchedulerDecision::Infer);
    }

    #[test]
    fn adapts_when_link_slows_down() {
        let mut s = StageScheduler::new(16);
        s.observe_infer_cost(1.0);
        // fast stages first: skipping
        let mut t = 0.0;
        let mut skipped = false;
        for i in 0..6 {
            t += 0.1;
            if s.on_stage_complete(i, t) == SchedulerDecision::Skip {
                skipped = true;
            }
            s.observe_infer_cost(1.0);
        }
        assert!(skipped);
        // link collapses to 10s gaps: inference fits again
        for i in 6..10 {
            t += 10.0;
            let d = s.on_stage_complete(i, t);
            if i > 7 {
                assert_eq!(d, SchedulerDecision::Infer, "stage {i}");
            }
            s.observe_infer_cost(1.0);
        }
    }

    #[test]
    fn interleave_covers_all_stages_in_order() {
        let models = vec![
            InterleaveModel {
                name: "a".into(),
                first_stage: 1,
                stage_bytes: vec![100; 7],
                priority: 1.0,
            },
            InterleaveModel {
                name: "b".into(),
                first_stage: 1,
                stage_bytes: vec![100; 7],
                priority: 1.0,
            },
        ];
        let plan = interleave_stages(&models);
        assert_eq!(plan.len(), 14);
        for name in ["a", "b"] {
            let stages: Vec<usize> = plan
                .iter()
                .filter(|e| e.model == name)
                .map(|e| e.stage)
                .collect();
            assert_eq!(stages, (1..8).collect::<Vec<_>>(), "model {name}");
        }
        // equal sizes + priorities → strict alternation
        for pair in plan.chunks(2) {
            assert_ne!(pair[0].model, pair[1].model);
        }
    }

    #[test]
    fn interleave_respects_priority() {
        let models = vec![
            InterleaveModel {
                name: "hot".into(),
                first_stage: 0,
                stage_bytes: vec![100; 8],
                priority: 4.0,
            },
            InterleaveModel {
                name: "cold".into(),
                first_stage: 0,
                stage_bytes: vec![100; 8],
                priority: 1.0,
            },
        ];
        let plan = interleave_stages(&models);
        assert_eq!(plan.len(), 16);
        // the high-priority model finishes its stages strictly earlier
        let last = |name: &str| plan.iter().rposition(|e| e.model == name).unwrap();
        assert!(last("hot") < last("cold"));
        // and gets more of the early slots
        let hot_early = plan[..8].iter().filter(|e| e.model == "hot").count();
        assert!(hot_early >= 6, "hot got only {hot_early} of the first 8 slots");
    }

    #[test]
    fn interleave_weighs_stage_sizes() {
        // a model with tiny stages should slip its stages between the
        // big ones even at equal priority
        let models = vec![
            InterleaveModel {
                name: "big".into(),
                first_stage: 0,
                stage_bytes: vec![1000; 4],
                priority: 1.0,
            },
            InterleaveModel {
                name: "small".into(),
                first_stage: 0,
                stage_bytes: vec![10; 4],
                priority: 1.0,
            },
        ];
        let plan = interleave_stages(&models);
        // all small stages are planned before the second big stage
        let second_big = plan
            .iter()
            .enumerate()
            .filter(|(_, e)| e.model == "big")
            .nth(1)
            .unwrap()
            .0;
        let last_small = plan.iter().rposition(|e| e.model == "small").unwrap();
        assert!(last_small < second_big, "{plan:?}");
    }

    #[test]
    fn ewma_tracks() {
        let mut s = StageScheduler::new(4);
        s.observe_infer_cost(1.0);
        s.observe_infer_cost(2.0);
        let c = s.estimated_infer_cost();
        assert!(c > 1.0 && c < 2.0);
    }
}
