//! Shared coordinator state: hot-swappable weights + client session table.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex, RwLock};

use crate::runtime::{ApproxModel, ModelSession};

pub use crate::runtime::WeightsVersion;

/// Versioned, hot-swappable flat weights — a standalone weight cell not
/// yet bound to a compiled session.
///
/// The progressive client publishes each stage's reconstruction here; the
/// batcher snapshots an `Arc` per batch, so refinement never blocks
/// in-flight inference. [`WeightStore::bind`] attaches a session, turning
/// the cell into a servable [`ApproxModel`] that shares the same storage.
#[derive(Clone)]
pub struct WeightStore {
    inner: Arc<RwLock<WeightsVersion>>,
}

impl WeightStore {
    pub fn empty(param_count: usize) -> Self {
        Self {
            inner: Arc::new(RwLock::new(WeightsVersion {
                flat: Arc::new(vec![0f32; param_count]),
                cum_bits: 0,
                version: 0,
            })),
        }
    }

    /// Publish a refined snapshot (copies the slice once).
    pub fn publish(&self, flat: &[f32], cum_bits: u32) {
        let mut w = self.inner.write().unwrap();
        assert_eq!(flat.len(), w.flat.len(), "param count changed");
        w.flat = Arc::new(flat.to_vec());
        w.cum_bits = cum_bits;
        w.version += 1;
    }

    /// Snapshot the current weights (cheap Arc clone).
    pub fn snapshot(&self) -> WeightsVersion {
        self.inner.read().unwrap().clone()
    }

    /// Has any stage been published yet?
    pub fn ready(&self) -> bool {
        self.inner.read().unwrap().version > 0
    }

    /// Attach a compiled session to this cell: the returned
    /// [`ApproxModel`] reads and writes the *same* versioned weights, so
    /// existing `publish` calls keep feeding the bound model.
    pub fn bind(&self, session: Arc<ModelSession>) -> ApproxModel {
        ApproxModel::over(session, self.inner.clone())
    }
}

/// Per-download-session progress (exposed by the e2e driver's status).
#[derive(Debug, Clone, Default)]
pub struct SessionState {
    pub model: String,
    pub stages_complete: usize,
    pub cum_bits: u32,
    pub bytes_received: u64,
    pub total_bytes: u64,
    pub done: bool,
}

/// Thread-safe session table keyed by session id.
#[derive(Default)]
pub struct SessionTable {
    next_id: AtomicU64,
    sessions: Mutex<HashMap<u64, SessionState>>,
}

impl SessionTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create(&self, model: &str) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.sessions.lock().unwrap().insert(
            id,
            SessionState {
                model: model.to_string(),
                ..Default::default()
            },
        );
        id
    }

    pub fn update<F: FnOnce(&mut SessionState)>(&self, id: u64, f: F) {
        if let Some(s) = self.sessions.lock().unwrap().get_mut(&id) {
            f(s);
        }
    }

    pub fn get(&self, id: u64) -> Option<SessionState> {
        self.sessions.lock().unwrap().get(&id).cloned()
    }

    pub fn remove(&self, id: u64) -> Option<SessionState> {
        self.sessions.lock().unwrap().remove(&id)
    }

    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All sessions (for status dumps).
    pub fn snapshot(&self) -> Vec<(u64, SessionState)> {
        let mut v: Vec<_> = self
            .sessions
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (*k, s.clone()))
            .collect();
        v.sort_by_key(|(k, _)| *k);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_store_versioning() {
        let ws = WeightStore::empty(4);
        assert!(!ws.ready());
        ws.publish(&[1.0, 2.0, 3.0, 4.0], 2);
        let v1 = ws.snapshot();
        assert_eq!(v1.version, 1);
        assert_eq!(v1.cum_bits, 2);
        ws.publish(&[1.1, 2.1, 3.1, 4.1], 4);
        let v2 = ws.snapshot();
        assert_eq!(v2.version, 2);
        // old snapshot is unaffected (hot swap semantics)
        assert_eq!(v1.flat[0], 1.0);
        assert_eq!(v2.flat[0], 1.1);
    }

    #[test]
    #[should_panic(expected = "param count changed")]
    fn publish_wrong_size_panics() {
        let ws = WeightStore::empty(4);
        ws.publish(&[0.0; 3], 2);
    }

    #[test]
    fn session_table_crud() {
        let t = SessionTable::new();
        let a = t.create("cnn");
        let b = t.create("mlp");
        assert_ne!(a, b);
        assert_eq!(t.len(), 2);
        t.update(a, |s| {
            s.stages_complete = 3;
            s.cum_bits = 6;
        });
        assert_eq!(t.get(a).unwrap().stages_complete, 3);
        assert_eq!(t.snapshot().len(), 2);
        t.remove(a);
        assert_eq!(t.len(), 1);
        assert!(t.get(a).is_none());
    }

    #[test]
    fn bound_approx_model_shares_the_cell() {
        let reg = crate::testutil::fixture::executable_models("state-bind").unwrap();
        let m = reg.get("dense3").unwrap();
        let engine = crate::runtime::Engine::reference();
        let session = Arc::new(crate::runtime::ModelSession::load(&engine, m).unwrap());
        let ws = WeightStore::empty(m.param_count);
        let approx = ws.bind(session);
        assert!(!approx.ready());
        // a publish through the store is visible through the model …
        ws.publish(&m.load_weights().unwrap(), 16);
        assert!(approx.ready());
        assert_eq!(approx.cum_bits(), 16);
        // … and vice versa
        approx.publish(&vec![0.0; m.param_count], 2);
        assert_eq!(ws.snapshot().cum_bits, 2);
        assert_eq!(ws.snapshot().version, 2);
    }

    #[test]
    fn concurrent_publish_and_snapshot() {
        let ws = WeightStore::empty(128);
        let ws2 = ws.clone();
        let writer = std::thread::spawn(move || {
            for i in 1..=50u32 {
                ws2.publish(&vec![i as f32; 128], (i % 16) + 1);
            }
        });
        let mut last = 0;
        for _ in 0..200 {
            let v = ws.snapshot();
            assert!(v.version >= last, "versions must not go backwards");
            last = v.version;
        }
        writer.join().unwrap();
        assert_eq!(ws.snapshot().version, 50);
    }
}
