//! Multi-client serving coordinator — the L3 "serving framework" layer.
//!
//! The progressive client ([`crate::client`]) refines a model in place
//! while this coordinator serves inference requests against whatever
//! approximation is currently available:
//!
//! - [`state::WeightStore`] — hot-swappable weights (stage refinements
//!   are published atomically; in-flight batches keep the snapshot they
//!   started with). Binds to a compiled session as an
//!   [`ApproxModel`](crate::runtime::ApproxModel).
//! - [`batcher::Batcher`] — dynamic batching per model (max-batch /
//!   max-delay policy, like vLLM-style serving front-ends), bound to an
//!   `ApproxModel` so batches serve mid-download reconstructions.
//! - [`router::Router`] — routes requests by model id to its batcher;
//!   [`Router::bind`] attaches a progressive session's `ApproxModel`.
//! - [`scheduler::StageScheduler`] — §III-C decision logic: which
//!   completed stages to run inference on, given measured inference cost
//!   vs stage inter-arrival time.

#![forbid(unsafe_code)]

pub mod batcher;
pub mod router;
pub mod scheduler;
pub mod state;

pub use batcher::{Batcher, BatcherConfig, InferReply};
pub use router::Router;
pub use scheduler::{
    interleave_stages, InterleaveModel, SchedulerDecision, StagePlanEntry, StageScheduler,
};
pub use state::{SessionState, SessionTable, WeightStore, WeightsVersion};
