//! Request router: model id → its dynamic batcher (lazily started).
//!
//! Each lane binds a [`Batcher`] to a hot-swappable
//! [`ApproxModel`](crate::runtime::ApproxModel): lanes created lazily get
//! a fresh empty cell fed via [`Router::publish_weights`], while
//! [`Router::bind`] attaches an externally-driven handle (typically from
//! a `client::session::ProgressiveSession`) so the router serves a model
//! that is still downloading and upgrades as stages complete.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use crate::util::sync::{Arc, Mutex};

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig, InferReply};
use crate::models::Registry;
use crate::runtime::{ApproxModel, Engine, ModelSession};

/// Multi-model inference front-end.
pub struct Router {
    engine: Engine,
    registry: Registry,
    config: BatcherConfig,
    lanes: Mutex<HashMap<String, Arc<Lane>>>,
}

struct Lane {
    batcher: Batcher,
    model: ApproxModel,
}

impl Router {
    pub fn new(engine: Engine, registry: Registry, config: BatcherConfig) -> Self {
        Self {
            engine,
            registry,
            config,
            lanes: Mutex::new(HashMap::new()),
        }
    }

    fn lane(&self, model: &str) -> Result<Arc<Lane>> {
        if let Some(l) = self.lanes.lock().unwrap().get(model) {
            return Ok(l.clone());
        }
        // Build outside the lock (compilation can take a moment).
        let manifest = self.registry.get(model)?;
        let session = Arc::new(ModelSession::load_batches(
            &self.engine,
            manifest,
            &manifest.fwd_batches(),
        )?);
        let approx = ApproxModel::new(session);
        let batcher = Batcher::bind(approx.clone(), self.config.clone());
        let lane = Arc::new(Lane {
            batcher,
            model: approx,
        });
        let mut lanes = self.lanes.lock().unwrap();
        // another thread may have raced us; keep the first
        Ok(lanes.entry(model.to_string()).or_insert(lane).clone())
    }

    /// Bind an externally-driven [`ApproxModel`] as this model's lane: the
    /// batcher serves every request against the handle's newest snapshot,
    /// so a progressive session publishing into it makes the lane answer
    /// mid-download and upgrade in place. Replaces any existing lane.
    pub fn bind(&self, model: &str, approx: ApproxModel) {
        let batcher = Batcher::bind(approx.clone(), self.config.clone());
        let lane = Arc::new(Lane {
            batcher,
            model: approx,
        });
        self.lanes.lock().unwrap().insert(model.to_string(), lane);
    }

    /// The hot-swappable handle of an existing lane (lazy lanes are not
    /// created by this accessor).
    pub fn approx(&self, model: &str) -> Option<ApproxModel> {
        self.lanes
            .lock()
            .unwrap()
            .get(model)
            .map(|l| l.model.clone())
    }

    /// Publish refined weights for a model (from the progressive client).
    pub fn publish_weights(&self, model: &str, flat: &[f32], cum_bits: u32) -> Result<()> {
        let lane = self.lane(model)?;
        lane.model.publish(flat, cum_bits);
        Ok(())
    }

    /// Is this model ready to serve (any weights published)?
    pub fn model_ready(&self, model: &str) -> bool {
        self.lanes
            .lock()
            .unwrap()
            .get(model)
            .map(|l| l.model.ready())
            .unwrap_or(false)
    }

    /// Route one request (blocking until the reply arrives).
    pub fn infer(&self, model: &str, image: Vec<f32>) -> Result<InferReply> {
        let lane = self.lane(model)?;
        anyhow::ensure!(
            lane.model.ready(),
            "model '{model}' has no published weights yet"
        );
        lane.batcher.infer_blocking(image)
    }

    /// Route one request asynchronously.
    pub fn submit(
        &self,
        model: &str,
        image: Vec<f32>,
    ) -> Result<std::sync::mpsc::Receiver<InferReply>> {
        let lane = self.lane(model)?;
        anyhow::ensure!(
            lane.model.ready(),
            "model '{model}' has no published weights yet"
        );
        lane.batcher.submit(image)
    }

    /// Latency stats for a model's lane.
    pub fn latency_stats(&self, model: &str) -> Option<crate::metrics::Histogram> {
        self.lanes
            .lock()
            .unwrap()
            .get(model)
            .map(|l| l.batcher.latency_stats())
    }

    pub fn active_models(&self) -> Vec<String> {
        self.lanes.lock().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<Router> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let engine = Engine::global().unwrap();
        let registry = Registry::open_default().unwrap();
        Some(Router::new(engine, registry, BatcherConfig::default()))
    }

    #[test]
    fn routes_by_model_and_requires_weights() {
        let Some(router) = setup() else { return };
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let img = vec![0.5f32; m.input_numel()];
        // before weights published: refuse
        assert!(router.infer("mlp", img.clone()).is_err());
        router
            .publish_weights("mlp", &m.load_weights().unwrap(), 16)
            .unwrap();
        assert!(router.model_ready("mlp"));
        let r = router.infer("mlp", img).unwrap();
        assert_eq!(r.output.unwrap().len(), 10);
        assert!(router.active_models().contains(&"mlp".to_string()));
    }

    #[test]
    fn bound_lane_serves_external_approx_model() {
        // fixture-backed (runs without artifacts): a lane bound to an
        // external ApproxModel serves whatever its driver publishes
        let reg = crate::testutil::fixture::executable_models("router-bind").unwrap();
        let m = reg.get("dense3").unwrap().clone();
        let engine = Engine::reference();
        let router = Router::new(
            engine.clone(),
            crate::testutil::fixture::executable_models("router-bind2").unwrap(),
            BatcherConfig::default(),
        );
        let session = Arc::new(ModelSession::load(&engine, &m).unwrap());
        let approx = ApproxModel::new(session);
        router.bind("dense3", approx.clone());
        assert!(!router.model_ready("dense3"));
        assert!(router.approx("dense3").is_some());
        approx.publish(&m.load_weights().unwrap(), 16);
        assert!(router.model_ready("dense3"));
        let r = router.infer("dense3", vec![0.4f32; m.input_numel()]).unwrap();
        assert_eq!(r.cum_bits, 16);
        assert_eq!(r.output.unwrap().len(), m.classes);
    }

    #[test]
    fn unknown_model_is_error() {
        let Some(router) = setup() else { return };
        assert!(router.infer("nope", vec![0.0; 10]).is_err());
    }

    #[test]
    fn two_models_serve_independently() {
        let Some(router) = setup() else { return };
        let reg = Registry::open_default().unwrap();
        for name in ["mlp", "cnn"] {
            let m = reg.get(name).unwrap();
            router
                .publish_weights(name, &m.load_weights().unwrap(), 16)
                .unwrap();
        }
        let mlp = reg.get("mlp").unwrap();
        let cnn = reg.get("cnn").unwrap();
        let a = router
            .infer("mlp", vec![0.3f32; mlp.input_numel()])
            .unwrap();
        let b = router
            .infer("cnn", vec![0.3f32; cnn.input_numel()])
            .unwrap();
        assert_eq!(a.output.unwrap().len(), 10);
        assert_eq!(b.output.unwrap().len(), 10);
        assert_eq!(router.active_models().len(), 2);
    }
}
