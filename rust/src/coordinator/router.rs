//! Request router: model id → its dynamic batcher (lazily started).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig, InferReply};
use super::state::WeightStore;
use crate::models::Registry;
use crate::runtime::{Engine, ModelSession};

/// Multi-model inference front-end.
pub struct Router {
    engine: Engine,
    registry: Registry,
    config: BatcherConfig,
    lanes: Mutex<HashMap<String, Arc<Lane>>>,
}

struct Lane {
    batcher: Batcher,
    weights: WeightStore,
}

impl Router {
    pub fn new(engine: Engine, registry: Registry, config: BatcherConfig) -> Self {
        Self {
            engine,
            registry,
            config,
            lanes: Mutex::new(HashMap::new()),
        }
    }

    fn lane(&self, model: &str) -> Result<Arc<Lane>> {
        if let Some(l) = self.lanes.lock().unwrap().get(model) {
            return Ok(l.clone());
        }
        // Build outside the lock (compilation can take a moment).
        let manifest = self.registry.get(model)?;
        let session = Arc::new(ModelSession::load_batches(
            &self.engine,
            manifest,
            &manifest.fwd_batches(),
        )?);
        let weights = WeightStore::empty(manifest.param_count);
        let batcher = Batcher::start(session, weights.clone(), self.config.clone());
        let lane = Arc::new(Lane { batcher, weights });
        let mut lanes = self.lanes.lock().unwrap();
        // another thread may have raced us; keep the first
        Ok(lanes.entry(model.to_string()).or_insert(lane).clone())
    }

    /// Publish refined weights for a model (from the progressive client).
    pub fn publish_weights(&self, model: &str, flat: &[f32], cum_bits: u32) -> Result<()> {
        let lane = self.lane(model)?;
        lane.weights.publish(flat, cum_bits);
        Ok(())
    }

    /// Is this model ready to serve (any weights published)?
    pub fn model_ready(&self, model: &str) -> bool {
        self.lanes
            .lock()
            .unwrap()
            .get(model)
            .map(|l| l.weights.ready())
            .unwrap_or(false)
    }

    /// Route one request (blocking until the reply arrives).
    pub fn infer(&self, model: &str, image: Vec<f32>) -> Result<InferReply> {
        let lane = self.lane(model)?;
        anyhow::ensure!(
            lane.weights.ready(),
            "model '{model}' has no published weights yet"
        );
        lane.batcher.infer_blocking(image)
    }

    /// Route one request asynchronously.
    pub fn submit(
        &self,
        model: &str,
        image: Vec<f32>,
    ) -> Result<std::sync::mpsc::Receiver<InferReply>> {
        let lane = self.lane(model)?;
        anyhow::ensure!(
            lane.weights.ready(),
            "model '{model}' has no published weights yet"
        );
        lane.batcher.submit(image)
    }

    /// Latency stats for a model's lane.
    pub fn latency_stats(&self, model: &str) -> Option<crate::metrics::Histogram> {
        self.lanes
            .lock()
            .unwrap()
            .get(model)
            .map(|l| l.batcher.latency_stats())
    }

    pub fn active_models(&self) -> Vec<String> {
        self.lanes.lock().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> Option<Router> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let engine = Engine::global().unwrap();
        let registry = Registry::open_default().unwrap();
        Some(Router::new(engine, registry, BatcherConfig::default()))
    }

    #[test]
    fn routes_by_model_and_requires_weights() {
        let Some(router) = setup() else { return };
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let img = vec![0.5f32; m.input_numel()];
        // before weights published: refuse
        assert!(router.infer("mlp", img.clone()).is_err());
        router
            .publish_weights("mlp", &m.load_weights().unwrap(), 16)
            .unwrap();
        assert!(router.model_ready("mlp"));
        let r = router.infer("mlp", img).unwrap();
        assert_eq!(r.output.unwrap().len(), 10);
        assert!(router.active_models().contains(&"mlp".to_string()));
    }

    #[test]
    fn unknown_model_is_error() {
        let Some(router) = setup() else { return };
        assert!(router.infer("nope", vec![0.0; 10]).is_err());
    }

    #[test]
    fn two_models_serve_independently() {
        let Some(router) = setup() else { return };
        let reg = Registry::open_default().unwrap();
        for name in ["mlp", "cnn"] {
            let m = reg.get(name).unwrap();
            router
                .publish_weights(name, &m.load_weights().unwrap(), 16)
                .unwrap();
        }
        let mlp = reg.get("mlp").unwrap();
        let cnn = reg.get("cnn").unwrap();
        let a = router
            .infer("mlp", vec![0.3f32; mlp.input_numel()])
            .unwrap();
        let b = router
            .infer("cnn", vec![0.3f32; cnn.input_numel()])
            .unwrap();
        assert_eq!(a.output.unwrap().len(), 10);
        assert_eq!(b.output.unwrap().len(), 10);
        assert_eq!(router.active_models().len(), 2);
    }
}
