//! `prognet-lint`: zero-dependency, line-oriented enforcement of the
//! repo's concurrency invariants (the ones the compiler can't check and
//! review vigilance shouldn't have to).
//!
//! Rules (catalog + rationale: `rust/docs/ANALYSIS.md`):
//!
//! - `direct-sync-import` — sync primitives must come from the
//!   `util::sync` facade, not `std::sync`, or the model checker can't
//!   see them.
//! - `unsafe-outside-allowlist` — `unsafe` only in the quarantined FFI
//!   modules; everything else carries `#![forbid(unsafe_code)]`.
//! - `wall-clock-in-protocol` — protocol code takes time from the clock
//!   facade / an injected `Clock`, never `Instant::now()` directly.
//! - `alloc-in-hot-path` — no allocation between `// lint:hot-path` and
//!   `// lint:end-hot-path` markers.
//! - `ordering-relaxed-shared` — `Ordering::Relaxed` requires an
//!   explicit waiver explaining why no ordering is needed.
//! - `span-not-closed` — a span guard from `obs::begin`/`begin_child`
//!   must be bound, not discarded where it is made (RAII ends the span
//!   immediately, so a discarded guard records a zero-length span).
//! - `raw-retry-loop` — hand-rolled retry loops (a `for`/`while` header
//!   iterating over attempts/retries) are banned in protocol code; use
//!   `util::retry::RetryPolicy` so every reconnect shares one budgeted,
//!   jittered, clock-injected backoff schedule.
//!
//! Waivers: `// lint:allow <rule>` on the offending line, or a
//! `<rule> <path>` entry in `lint-allow.txt` (regenerate with
//! `prognet-lint --fix-allowlist`). Exits nonzero on violations.
//!
//! Run from `rust/`: `cargo run --bin prognet-lint`.

#![forbid(unsafe_code)]

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

const RULES: [&str; 7] = [
    "direct-sync-import",
    "unsafe-outside-allowlist",
    "wall-clock-in-protocol",
    "alloc-in-hot-path",
    "ordering-relaxed-shared",
    "span-not-closed",
    "raw-retry-loop",
];

/// Path prefixes whose non-test code is "protocol code" for the
/// wall-clock rule: state machines and caches whose timing behavior the
/// deterministic tests must control.
const PROTOCOL_PREFIXES: [&str; 5] = [
    "src/fleet/",
    "src/client/",
    "src/server/",
    "src/coordinator/",
    "src/netsim/",
];

/// Source tokens that allocate (scanned only inside hot-path regions).
const ALLOC_TOKENS: [&str; 8] = [
    "vec!",
    "Vec::new",
    "Vec::with_capacity",
    "String::new",
    "Box::new",
    "to_vec()",
    "to_string()",
    "format!",
];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    text: String,
}

/// File-level waivers parsed from `lint-allow.txt`.
#[derive(Default)]
struct AllowList {
    entries: BTreeSet<(String, String)>,
}

impl AllowList {
    fn parse(text: &str) -> Self {
        let mut entries = BTreeSet::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((rule, path)) = line.split_once(char::is_whitespace) {
                entries.insert((rule.trim().to_string(), path.trim().to_string()));
            }
        }
        Self { entries }
    }

    fn allows(&self, rule: &str, file: &str) -> bool {
        self.entries
            .contains(&(rule.to_string(), file.to_string()))
    }

    fn render(&self) -> String {
        let mut out = String::from(
            "# prognet-lint file-level waivers: `<rule> <path>` per line.\n\
             # Regenerate with `cargo run --bin prognet-lint -- --fix-allowlist`.\n",
        );
        for (rule, path) in &self.entries {
            out.push_str(rule);
            out.push(' ');
            out.push_str(path);
            out.push('\n');
        }
        out
    }
}

/// Code portion of a source line: strips a trailing `//` comment (which
/// also drops whole-line `//`/`//!`/`///` comments). A `//` inside a
/// string literal truncates too — acceptable for a line-oriented lint.
fn code_of(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Does the line carry an inline waiver for `rule`?
fn line_waives(line: &str, rule: &str) -> bool {
    line.find("lint:allow")
        .map(|i| line[i + "lint:allow".len()..].trim_start().starts_with(rule))
        .unwrap_or(false)
}

/// Word-boundary search: `needle` not embedded in a larger identifier.
fn has_word(code: &str, needle: &str) -> bool {
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut start = 0;
    while let Some(i) = code[start..].find(needle) {
        let at = start + i;
        let before_ok = at == 0 || !code[..at].chars().next_back().is_some_and(is_ident);
        let after_ok = !code[at + needle.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            return true;
        }
        start = at + needle.len();
    }
    false
}

fn is_protocol_file(file: &str) -> bool {
    PROTOCOL_PREFIXES.iter().any(|p| file.starts_with(p))
}

/// A hand-rolled retry loop: a `for`/`while` header driven by an
/// attempt/retry counter. Protocol code must route reconnects through
/// `util::retry::RetryPolicy` instead, so backoff schedules stay
/// budgeted, jittered and clock-injected (plain `loop {}` bodies whose
/// exits come from a `Retry::backoff()` call are fine — the header
/// carries no attempt arithmetic).
fn raw_retry_loop(code: &str) -> bool {
    let t = code.trim_start();
    if !(t.starts_with("for ") || t.starts_with("while ")) {
        return false;
    }
    ["attempt", "attempts", "retry", "retries", "retried"]
        .iter()
        .any(|w| has_word(code, w))
}

/// A span guard discarded at birth. Two line shapes, both of which drop
/// the guard — and therefore end the span — on the same statement:
/// a bare statement-position begin call (`obs::begin("x");` — no `=`
/// anywhere, so nothing binds the result), and an explicit `let _ =`
/// throwaway. Guards bound to names (including `_sp`) live to scope end
/// and are fine.
fn span_discarded(code: &str) -> bool {
    let has_begin = code.contains("obs::begin") || code.contains("span::begin");
    if !has_begin {
        return false;
    }
    let t = code.trim();
    if t.starts_with("let _ =") {
        return true;
    }
    t.ends_with(';') && !t.contains('=')
}

fn scan_file(file: &str, content: &str, allow: &AllowList) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut in_hot_path = false;
    let mut in_tests = false;
    let mut push = |rule: &'static str, lineno: usize, raw: &str| {
        if !allow.allows(rule, file) && !line_waives(raw, rule) {
            out.push(Violation {
                file: file.to_string(),
                line: lineno,
                rule,
                text: raw.trim().to_string(),
            });
        }
    };
    for (i, raw) in content.lines().enumerate() {
        let lineno = i + 1;
        // region / section markers come from the raw line (they live in
        // comments, which code_of strips); the end marker is checked
        // first because "lint:hot-path" is a substring of it
        if raw.contains("lint:end-hot-path") {
            in_hot_path = false;
            continue;
        }
        if raw.contains("lint:hot-path") {
            in_hot_path = true;
            continue;
        }
        if raw.trim_start().starts_with("#[cfg(test)]") {
            // repo convention: the test module is the tail of the file
            in_tests = true;
        }
        let code = code_of(raw);
        if code.trim().is_empty() {
            continue;
        }
        if code.contains("use std::sync::")
            || code.contains("std::sync::Mutex")
            || code.contains("std::sync::RwLock")
            || code.contains("std::sync::Condvar")
            || code.contains("std::sync::atomic::")
        {
            push("direct-sync-import", lineno, raw);
        }
        if has_word(code, "unsafe") {
            push("unsafe-outside-allowlist", lineno, raw);
        }
        if !in_tests
            && is_protocol_file(file)
            && (code.contains("Instant::now()")
                || code.contains("SystemTime::now()")
                || code.contains("thread::sleep"))
        {
            push("wall-clock-in-protocol", lineno, raw);
        }
        if in_hot_path && ALLOC_TOKENS.iter().any(|t| code.contains(t)) {
            push("alloc-in-hot-path", lineno, raw);
        }
        if !in_tests && code.contains("Ordering::Relaxed") {
            push("ordering-relaxed-shared", lineno, raw);
        }
        if !in_tests && span_discarded(code) {
            push("span-not-closed", lineno, raw);
        }
        if !in_tests && is_protocol_file(file) && raw_retry_loop(code) {
            push("raw-retry-loop", lineno, raw);
        }
    }
    out
}

fn rust_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![root.join("src")];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else {
            continue;
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    out
}

fn run(args: &[String]) -> i32 {
    let mut fix = false;
    let mut root = PathBuf::from(".");
    let mut allow_path: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--fix-allowlist" => fix = true,
            "--root" => match it.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("--root needs a path");
                    return 2;
                }
            },
            "--allowlist" => match it.next() {
                Some(p) => allow_path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--allowlist needs a path");
                    return 2;
                }
            },
            "--help" | "-h" => {
                eprintln!("usage: prognet-lint [--root DIR] [--allowlist FILE] [--fix-allowlist]");
                return 0;
            }
            other => {
                eprintln!("unknown argument: {other}");
                return 2;
            }
        }
    }
    let allow_path = allow_path.unwrap_or_else(|| root.join("lint-allow.txt"));
    let allow = match std::fs::read_to_string(&allow_path) {
        Ok(text) => AllowList::parse(&text),
        Err(_) => AllowList::default(),
    };

    let mut violations = Vec::new();
    for path in rust_files(&root) {
        let rel = path
            .strip_prefix(&root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(content) = std::fs::read_to_string(&path) else {
            continue;
        };
        violations.extend(scan_file(&rel, &content, &allow));
    }

    if fix {
        let mut next = AllowList {
            entries: allow.entries.clone(),
        };
        for v in &violations {
            next.entries.insert((v.rule.to_string(), v.file.clone()));
        }
        if let Err(e) = std::fs::write(&allow_path, next.render()) {
            eprintln!("cannot write {}: {e}", allow_path.display());
            return 2;
        }
        println!(
            "allowlist updated: {} waiver(s) in {}",
            next.entries.len(),
            allow_path.display()
        );
        return 0;
    }

    for v in &violations {
        println!("{}:{}: {} — {}", v.file, v.line, v.rule, v.text);
    }
    if violations.is_empty() {
        println!("prognet-lint: clean ({} rules)", RULES.len());
        0
    } else {
        println!("prognet-lint: {} violation(s)", violations.len());
        1
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(run(&args));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(file: &str, content: &str) -> Vec<&'static str> {
        scan_file(file, content, &AllowList::default())
            .into_iter()
            .map(|v| v.rule)
            .collect()
    }

    #[test]
    fn flags_direct_sync_import() {
        let src = "use std::sync::Mutex;\n";
        assert_eq!(scan("src/foo.rs", src), vec!["direct-sync-import"]);
        let ok = "use crate::util::sync::Mutex;\n";
        assert!(scan("src/foo.rs", ok).is_empty());
    }

    #[test]
    fn flags_inline_sync_paths_but_not_arc() {
        let src = "let m = std::sync::Mutex::new(0);\n";
        assert_eq!(scan("src/foo.rs", src), vec!["direct-sync-import"]);
        let ok = "let a = std::sync::Arc::new(0);\n";
        assert!(scan("src/foo.rs", ok).is_empty());
    }

    #[test]
    fn flags_unsafe_but_not_in_comments_or_idents() {
        assert_eq!(
            scan("src/foo.rs", "let x = unsafe { *p };\n"),
            vec!["unsafe-outside-allowlist"]
        );
        assert!(scan("src/foo.rs", "// unsafe is discussed here\n").is_empty());
        assert!(scan("src/foo.rs", "#![forbid(unsafe_code)]\n").is_empty());
    }

    #[test]
    fn wall_clock_only_in_protocol_paths_and_not_tests() {
        let src = "let t = Instant::now();\n";
        assert_eq!(scan("src/fleet/x.rs", src), vec!["wall-clock-in-protocol"]);
        assert!(scan("src/util/x.rs", src).is_empty());
        let tested = "#[cfg(test)]\nmod tests {\n    fn f() { let t = Instant::now(); }\n}\n";
        assert!(scan("src/fleet/x.rs", tested).is_empty());
    }

    #[test]
    fn alloc_flagged_only_inside_hot_regions() {
        let src = "fn f() {\n    let v = vec![1];\n}\n";
        assert!(scan("src/foo.rs", src).is_empty());
        let hot =
            "fn f() {\n    // lint:hot-path\n    let v = vec![1];\n    // lint:end-hot-path\n}\n";
        assert_eq!(scan("src/foo.rs", hot), vec!["alloc-in-hot-path"]);
    }

    #[test]
    fn relaxed_needs_a_waiver() {
        let src = "x.load(Ordering::Relaxed);\n";
        assert_eq!(scan("src/foo.rs", src), vec!["ordering-relaxed-shared"]);
        let waived = "x.load(Ordering::Relaxed); // lint:allow ordering-relaxed-shared\n";
        assert!(scan("src/foo.rs", waived).is_empty());
    }

    #[test]
    fn span_guard_discards_are_flagged() {
        assert_eq!(
            scan("src/foo.rs", "    obs::begin(\"client.request\");\n"),
            vec!["span-not-closed"]
        );
        assert_eq!(
            scan("src/foo.rs", "    crate::obs::begin_child(\"edge.cache\", ctx);\n"),
            vec!["span-not-closed"]
        );
        assert_eq!(
            scan("src/foo.rs", "    let _ = obs::begin(\"x\");\n"),
            vec!["span-not-closed"]
        );
        // a map that throws the guards away is still a discard
        assert_eq!(
            scan("src/foo.rs", "    req.trace.map(|ctx| obs::begin_child(\"n\", ctx));\n"),
            vec!["span-not-closed"]
        );
        // bound guards (even `_sp`) and expression-position begins are fine
        assert!(scan("src/foo.rs", "    let sp = obs::begin(\"x\");\n").is_empty());
        assert!(scan("src/foo.rs", "    let _sp = obs::begin(\"x\");\n").is_empty());
        assert!(scan(
            "src/foo.rs",
            "    let s = req.trace.map(|ctx| obs::begin_child(\"n\", ctx));\n"
        )
        .is_empty());
        assert!(
            scan("src/foo.rs", "        span.map(|ctx| obs::begin_child(\"edge.relay\", ctx))\n")
                .is_empty()
        );
        // test modules may discard guards deliberately
        let tested = "#[cfg(test)]\nmod tests {\n    fn f() { obs::begin(\"t\"); }\n}\n";
        assert!(scan("src/foo.rs", tested).is_empty());
    }

    #[test]
    fn raw_retry_loops_flagged_in_protocol_code_only() {
        let src = "for attempt in 0..3 {\n";
        assert_eq!(scan("src/fleet/x.rs", src), vec!["raw-retry-loop"]);
        assert_eq!(
            scan("src/client/x.rs", "while retries < max_retries {\n"),
            vec!["raw-retry-loop"]
        );
        // non-protocol paths, RetryPolicy-driven loops, and identifiers
        // that merely embed the words are all fine
        assert!(scan("src/util/retry.rs", src).is_empty());
        assert!(scan("src/fleet/x.rs", "loop {\n").is_empty());
        assert!(scan("src/fleet/x.rs", "for x in reentry_points {\n").is_empty());
        // test modules may hand-roll loops to probe the retry machinery
        let tested = "#[cfg(test)]\nmod tests {\n    fn f() { for attempt in 0..3 {} }\n}\n";
        assert!(scan("src/fleet/x.rs", tested).is_empty());
    }

    #[test]
    fn file_allowlist_waives() {
        let allow = AllowList::parse("direct-sync-import src/foo.rs\n");
        let v = scan_file("src/foo.rs", "use std::sync::Mutex;\n", &allow);
        assert!(v.is_empty());
        let v = scan_file("src/bar.rs", "use std::sync::Mutex;\n", &allow);
        assert_eq!(v.len(), 1);
    }

    #[test]
    fn allowlist_roundtrips_through_render() {
        let a = AllowList::parse("b-rule src/b.rs\na-rule src/a.rs\n# comment\n");
        let b = AllowList::parse(&a.render());
        assert_eq!(a.entries, b.entries);
    }

    #[test]
    fn repo_tree_is_clean() {
        // the committed tree must lint clean with the committed allowlist
        // (CI runs the binary; this is the in-process equivalent)
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let allow_text =
            std::fs::read_to_string(root.join("lint-allow.txt")).unwrap_or_default();
        let allow = AllowList::parse(&allow_text);
        let mut violations = Vec::new();
        for path in rust_files(&root) {
            let rel = path
                .strip_prefix(&root)
                .unwrap()
                .to_string_lossy()
                .replace('\\', "/");
            let content = std::fs::read_to_string(&path).unwrap();
            violations.extend(scan_file(&rel, &content, &allow));
        }
        assert!(
            violations.is_empty(),
            "lint violations in tree:\n{}",
            violations
                .iter()
                .map(|v| format!("{}:{}: {} — {}", v.file, v.line, v.rule, v.text))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
