//! Deterministic network simulator: token-bucket bandwidth shaping with
//! latency, in two flavours:
//!
//! - [`Link`] — a *virtual-time* model used by the analytical harnesses
//!   (Table I timeline math without wall-clock sleeping).
//! - [`ThrottledWriter`] — *real-time* shaping applied to the
//!   server's socket writes, so end-to-end runs experience the configured
//!   MB/s on a real TCP connection.
//!
//! The paper's experiments use 0.1 / 0.2 / 0.5 / 1.0 / 2.5 MB/s links;
//! [`LinkSpec`] captures those configurations.

pub mod link;
pub mod throttle;
pub mod trace;

pub use link::{Link, LinkSpec};
pub use trace::{BandwidthTrace, TraceLink};
pub use throttle::ThrottledWriter;
