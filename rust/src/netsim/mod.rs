//! Deterministic network simulator: token-bucket bandwidth shaping with
//! latency, in two flavours:
//!
//! - [`Link`] — a *virtual-time* model used by the analytical harnesses
//!   (Table I timeline math without wall-clock sleeping).
//! - [`TokenBucket`] — shared *real-time* pacing math: the fleet
//!   reactor evaluates it nonblockingly so every server connection
//!   experiences the configured MB/s without a thread per client, and
//! - [`ThrottledWriter`] — the blocking `Write` adapter over the same
//!   bucket, for callers that can afford to sleep.
//!
//! The paper's experiments use 0.1 / 0.2 / 0.5 / 1.0 / 2.5 MB/s links;
//! [`LinkSpec`] captures those configurations.
//!
//! [`fault`] adds the adversarial half of the simulator: a
//! deterministic fault-injecting proxy ([`FaultProxy`]) that severs,
//! delays and corrupts connections on a seeded schedule — the primitive
//! behind `fleet::chaos` and the `prognet cluster --chaos` harness.

#![forbid(unsafe_code)]

pub mod fault;
pub mod link;
pub mod throttle;
pub mod trace;

pub use fault::{ConnFaults, FaultProxy, FaultSpec, FaultStats};
pub use link::{Link, LinkSpec};
pub use trace::{BandwidthTrace, TraceLink};
pub use throttle::{ThrottledWriter, TokenBucket};
