//! Bandwidth-trace links: piecewise-constant rate playback.
//!
//! Real mobile links are not constant-rate; a [`BandwidthTrace`] replays
//! `(duration, bytes/s)` segments (e.g. a 3G trace) in virtual time, so
//! the Table I / user-study harnesses can be driven by realistic traces
//! as well as the paper's fixed speeds.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// Piecewise-constant bandwidth trace. Loops after the last segment.
#[derive(Debug, Clone)]
pub struct BandwidthTrace {
    /// (duration seconds, bytes per second)
    segments: Vec<(f64, f64)>,
    total_dur: f64,
}

impl BandwidthTrace {
    pub fn new(segments: Vec<(f64, f64)>) -> Result<Self> {
        if segments.is_empty() {
            bail!("trace needs at least one segment");
        }
        if segments.iter().any(|&(d, r)| d <= 0.0 || r <= 0.0) {
            bail!("durations and rates must be positive");
        }
        let total_dur = segments.iter().map(|s| s.0).sum();
        Ok(Self {
            segments,
            total_dur,
        })
    }

    /// Constant-rate trace (equivalent to `LinkSpec::mbps`).
    pub fn constant(bytes_per_sec: f64) -> Self {
        Self::new(vec![(f64::INFINITY, bytes_per_sec)]).unwrap_or(Self {
            segments: vec![(f64::INFINITY, bytes_per_sec)],
            total_dur: f64::INFINITY,
        })
    }

    /// Parse "dur:rate_mbps,dur:rate_mbps,…" (CLI / config format).
    pub fn parse(text: &str) -> Result<Self> {
        let mut segments = Vec::new();
        for part in text.split(',').filter(|s| !s.is_empty()) {
            let (d, r) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("segment '{part}' is not dur:rate"))?;
            segments.push((
                d.trim().parse::<f64>()?,
                r.trim().parse::<f64>()? * 1024.0 * 1024.0,
            ));
        }
        Self::new(segments)
    }

    /// Duration of one period (`f64::INFINITY` for constant traces) —
    /// lets cohort builders sample a trace proportionally
    /// (`fleet::loadgen`).
    pub fn period(&self) -> f64 {
        self.total_dur
    }

    /// Rate at virtual time `t` (loops).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut t = if self.total_dur.is_finite() && t >= self.total_dur {
            t % self.total_dur
        } else {
            t
        };
        for &(d, r) in &self.segments {
            if t < d {
                return r;
            }
            t -= d;
        }
        self.segments.last().unwrap().1
    }

    /// Virtual time needed to deliver `bytes` starting at time `t0`.
    pub fn transfer_time_from(&self, t0: f64, bytes: u64) -> f64 {
        let mut remaining = bytes as f64;
        let mut t = t0;
        let mut guard = 0;
        while remaining > 1e-9 {
            let rate = self.rate_at(t);
            // time left in this segment
            let seg_left = self.time_to_segment_end(t);
            let deliverable = rate * seg_left;
            if deliverable >= remaining {
                return t + remaining / rate - t0;
            }
            remaining -= deliverable;
            t += seg_left;
            guard += 1;
            if guard > 1_000_000 {
                return f64::INFINITY; // pathological trace
            }
        }
        t - t0
    }

    fn time_to_segment_end(&self, t: f64) -> f64 {
        if !self.total_dur.is_finite() {
            return f64::INFINITY;
        }
        let mut local = t % self.total_dur;
        for &(d, _) in &self.segments {
            if local < d {
                return d - local;
            }
            local -= d;
        }
        self.segments.last().unwrap().0
    }

    /// Mean rate over one period.
    pub fn mean_rate(&self) -> f64 {
        if !self.total_dur.is_finite() {
            return self.segments[0].1;
        }
        let weighted: f64 = self.segments.iter().map(|&(d, r)| d * r).sum();
        weighted / self.total_dur
    }
}

/// Virtual-time cursor over a trace (trace analogue of [`super::Link`]).
#[derive(Debug, Clone)]
pub struct TraceLink {
    trace: BandwidthTrace,
    now: f64,
    delivered: u64,
}

impl TraceLink {
    pub fn new(trace: BandwidthTrace) -> Self {
        Self {
            trace,
            now: 0.0,
            delivered: 0,
        }
    }

    /// Queue `bytes`; returns virtual completion time.
    pub fn send(&mut self, bytes: u64) -> f64 {
        let dt = self.trace.transfer_time_from(self.now, bytes);
        self.now += dt;
        self.delivered += bytes;
        self.now
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn delivered(&self) -> u64 {
        self.delivered
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_trace_matches_linkspec() {
        let t = BandwidthTrace::constant(1024.0 * 1024.0);
        assert!((t.transfer_time_from(0.0, 2 * 1024 * 1024) - 2.0).abs() < 1e-9);
        assert_eq!(t.rate_at(1234.5), 1024.0 * 1024.0);
    }

    #[test]
    fn two_segment_split() {
        // 1s @ 1MB/s then 1s @ 2MB/s, looping; 2.5MB starting at t=0:
        // 1MB in first second, 1.5MB needs 0.75s of the 2MB/s segment.
        let mb = 1024.0 * 1024.0;
        let t = BandwidthTrace::new(vec![(1.0, mb), (1.0, 2.0 * mb)]).unwrap();
        let dt = t.transfer_time_from(0.0, (2.5 * mb) as u64);
        assert!((dt - 1.75).abs() < 1e-6, "dt={dt}");
    }

    #[test]
    fn looping_and_offset_start() {
        let mb = 1024.0 * 1024.0;
        let t = BandwidthTrace::new(vec![(1.0, mb), (1.0, 3.0 * mb)]).unwrap();
        // starting mid-fast-segment
        let dt = t.transfer_time_from(1.5, (1.5 * mb) as u64);
        // 0.5s of 3MB/s → 1.5MB done exactly at segment end
        assert!((dt - 0.5).abs() < 1e-6, "dt={dt}");
        // mean rate = 2 MB/s
        assert!((t.mean_rate() - 2.0 * mb).abs() < 1.0);
    }

    #[test]
    fn parse_format() {
        let t = BandwidthTrace::parse("2:0.5,1:2.0").unwrap();
        assert_eq!(t.segments.len(), 2);
        assert!((t.rate_at(0.0) - 0.5 * 1024.0 * 1024.0).abs() < 1e-6);
        assert!(BandwidthTrace::parse("bad").is_err());
        assert!(BandwidthTrace::parse("1:-2").is_err());
        assert!(BandwidthTrace::parse("").is_err());
    }

    #[test]
    fn trace_link_accumulates() {
        let mb = 1024.0 * 1024.0;
        let mut link = TraceLink::new(BandwidthTrace::new(vec![(1.0, mb)]).unwrap());
        let t1 = link.send((0.5 * mb) as u64);
        let t2 = link.send((0.5 * mb) as u64);
        assert!((t1 - 0.5).abs() < 1e-9);
        assert!((t2 - 1.0).abs() < 1e-9);
        assert_eq!(link.delivered(), mb as u64);
    }

    #[test]
    fn slow_fast_trace_vs_constant_same_mean() {
        // A bursty trace with the same mean rate delivers a large file in
        // approximately the same time (± one period).
        let mb = 1024.0 * 1024.0;
        let bursty = BandwidthTrace::new(vec![(1.0, 0.5 * mb), (1.0, 1.5 * mb)]).unwrap();
        let steady = BandwidthTrace::constant(mb);
        let size = (20.0 * mb) as u64;
        let a = bursty.transfer_time_from(0.0, size);
        let b = steady.transfer_time_from(0.0, size);
        assert!((a - b).abs() <= 2.0, "bursty {a} vs steady {b}");
    }
}
