//! Deterministic fault injection for network paths: a scriptable TCP
//! proxy that severs, delays, corrupts and throttles traffic on a seeded
//! schedule.
//!
//! The proxy is the fleet's chaos primitive (see `fleet::chaos`): placed
//! in front of a router it cuts client connections mid-frame; placed in
//! front of an origin it doubles as the stable address that lets the
//! cluster kill and restart the real server behind it without rebinding
//! a port. Every decision is a pure function of `(seed, connection
//! number)`, so a fixed seed replays the identical fault sequence —
//! chaos runs are reproducible, never flaky-by-design.
//!
//! Spec grammar (comma-separated rules; fields are `:`-separated
//! `key=value` pairs; see `docs/ROBUSTNESS.md`):
//!
//! ```text
//! sever:after=12000            cut every connection after 12000 bytes
//! sever:after=8000:conn=1      … only connection #1 (1-based)
//! sever:after=8000:every=3     … every 3rd connection
//! sever:after=8000:p=0.25      … each connection with probability 0.25
//! corrupt:at=64:mask=40        XOR downstream byte 64 with 0x40
//! delay:ms=50                  hold the accepted connection 50 ms
//! seed=42                      seed for the p= decisions (default 0)
//! ```
//!
//! Rules compose: a connection can be delayed, corrupted *and* severed.
//! `sever` counts downstream (server→client) bytes, so a cut lands
//! mid-frame from the client's point of view; `corrupt` flips bits in
//! flight without changing length, exercising CRC revalidation paths.

#![forbid(unsafe_code)]

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::BandwidthTrace;
use crate::util::rng::Rng;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{Arc, Clock, Mutex};

/// Which connections a rule applies to.
#[derive(Debug, Clone, PartialEq)]
enum Select {
    /// every connection
    All,
    /// exactly the n-th accepted connection (1-based)
    Conn(u64),
    /// every k-th connection (k, 2k, …)
    Every(u64),
    /// each connection independently with probability p (seeded)
    Prob(f64),
}

impl Select {
    fn applies(&self, conn_no: u64, rng: &mut Rng) -> bool {
        match *self {
            Select::All => true,
            Select::Conn(n) => conn_no == n,
            Select::Every(k) => k > 0 && conn_no % k == 0,
            Select::Prob(p) => rng.f64() < p,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Action {
    /// cut the connection after this many downstream bytes
    Sever { after: u64 },
    /// XOR the downstream byte at this absolute offset with `mask`
    Corrupt { at: u64, mask: u8 },
    /// hold the accepted connection before forwarding anything
    Delay { by: Duration },
}

#[derive(Debug, Clone, PartialEq)]
struct Rule {
    action: Action,
    select: Select,
}

/// Parsed fault script: an ordered rule list plus the decision seed.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    rules: Vec<Rule>,
    seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self::pass_through()
    }
}

/// The per-connection fault decision (resolved once at accept time).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConnFaults {
    /// hold the connection this long before forwarding
    pub delay: Option<Duration>,
    /// cut after this many downstream bytes (min across matching rules)
    pub sever_after: Option<u64>,
    /// (absolute downstream offset, XOR mask) byte corruptions
    pub corrupt: Vec<(u64, u8)>,
}

impl ConnFaults {
    pub fn is_clean(&self) -> bool {
        self.delay.is_none() && self.sever_after.is_none() && self.corrupt.is_empty()
    }
}

fn parse_field<'a>(field: &'a str, rule: &str) -> Result<(&'a str, &'a str)> {
    field
        .split_once('=')
        .with_context(|| format!("rule '{rule}': field '{field}' is not key=value"))
}

impl FaultSpec {
    /// A spec that forwards everything untouched.
    pub fn pass_through() -> Self {
        Self {
            rules: Vec::new(),
            seed: 0,
        }
    }

    /// Parse the comma-separated rule grammar (see module docs).
    pub fn parse(text: &str) -> Result<Self> {
        let mut rules = Vec::new();
        let mut seed = 0u64;
        for item in text.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(v) = item.strip_prefix("seed=") {
                seed = v.parse().with_context(|| format!("bad seed '{v}'"))?;
                continue;
            }
            let mut fields = item.split(':');
            let head = fields.next().unwrap_or_default();
            let mut select = Select::All;
            let mut kv: Vec<(&str, &str)> = Vec::new();
            for f in fields {
                let (k, v) = parse_field(f, item)?;
                match k {
                    "conn" => select = Select::Conn(v.parse()?),
                    "every" => select = Select::Every(v.parse()?),
                    "p" => select = Select::Prob(v.parse()?),
                    _ => kv.push((k, v)),
                }
            }
            let get = |key: &str| -> Result<&str> {
                kv.iter()
                    .find(|(k, _)| *k == key)
                    .map(|(_, v)| *v)
                    .with_context(|| format!("rule '{item}': missing {key}="))
            };
            let action = match head {
                "sever" => Action::Sever {
                    after: get("after")?.parse()?,
                },
                "corrupt" => Action::Corrupt {
                    at: get("at")?.parse()?,
                    mask: match kv.iter().find(|(k, _)| *k == "mask") {
                        Some((_, v)) => u8::from_str_radix(v, 16)
                            .with_context(|| format!("rule '{item}': bad hex mask '{v}'"))?,
                        None => 0x40,
                    },
                },
                "delay" => Action::Delay {
                    by: Duration::from_millis(get("ms")?.parse()?),
                },
                other => bail!("unknown fault action '{other}' in '{item}'"),
            };
            rules.push(Rule { action, select });
        }
        Ok(Self { rules, seed })
    }

    pub fn is_pass_through(&self) -> bool {
        self.rules.is_empty()
    }

    /// Resolve the faults for connection `conn_no` (1-based). Pure in
    /// `(seed, conn_no)`: probability rules draw from an RNG seeded by
    /// both, so the same connection always gets the same verdict.
    pub fn decide(&self, conn_no: u64) -> ConnFaults {
        let mut rng = Rng::new(self.seed ^ conn_no.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut out = ConnFaults::default();
        for rule in &self.rules {
            if !rule.select.applies(conn_no, &mut rng) {
                continue;
            }
            match rule.action {
                Action::Sever { after } => {
                    out.sever_after = Some(out.sever_after.map_or(after, |a| a.min(after)));
                }
                Action::Corrupt { at, mask } => out.corrupt.push((at, mask)),
                Action::Delay { by } => {
                    out.delay = Some(out.delay.map_or(by, |d| d + by));
                }
            }
        }
        out
    }
}

/// Live counters of a running [`FaultProxy`].
#[derive(Debug, Default)]
pub struct FaultStats {
    pub connections: AtomicU64,
    pub severed: AtomicU64,
    pub corrupted: AtomicU64,
    pub delayed: AtomicU64,
    /// connections refused because the proxy was marked down
    pub refused: AtomicU64,
}

struct ProxyInner {
    upstream: Mutex<SocketAddr>,
    /// marked-down proxies drop accepted connections immediately —
    /// "connection died before the status frame", the shape of a crashed
    /// backend
    down: AtomicBool,
    spec: FaultSpec,
    /// downstream shaping trace (None = unshaped); swap mid-run to model
    /// a bandwidth cliff
    shape: Mutex<Option<BandwidthTrace>>,
    clock: Clock,
    stats: Arc<FaultStats>,
    stop: Arc<AtomicBool>,
}

/// A fault-injecting TCP forwarder (shuts down on drop).
///
/// Request bytes (client→upstream) are forwarded verbatim on a pump
/// thread; response bytes (upstream→client) pass through the fault
/// engine: optional accept delay, scheduled corruption, mid-frame sever,
/// and optional [`BandwidthTrace`] shaping. The upstream address and the
/// down flag are swappable at runtime, which is what lets `fleet::chaos`
/// kill and restart the server behind a stable address.
pub struct FaultProxy {
    addr: SocketAddr,
    inner: Arc<ProxyInner>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl FaultProxy {
    pub fn start(upstream: SocketAddr, spec: FaultSpec, clock: Clock) -> Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding fault proxy")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let inner = Arc::new(ProxyInner {
            upstream: Mutex::new(upstream),
            down: AtomicBool::new(false),
            spec,
            shape: Mutex::new(None),
            clock,
            stats: Arc::new(FaultStats::default()),
            stop: stop.clone(),
        });
        let accept = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("prognet-fault-proxy".into())
                .spawn(move || accept_loop(listener, inner))?
        };
        Ok(Self {
            addr,
            inner,
            accept: Some(accept),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> Arc<FaultStats> {
        self.inner.stats.clone()
    }

    /// Swap the upstream address (a restarted backend on a fresh port).
    pub fn set_upstream(&self, upstream: SocketAddr) {
        *self.inner.upstream.lock().unwrap() = upstream;
    }

    pub fn upstream(&self) -> SocketAddr {
        *self.inner.upstream.lock().unwrap()
    }

    /// Mark the path down (accepted connections are dropped immediately)
    /// or back up.
    pub fn set_down(&self, down: bool) {
        self.inner.down.store(down, Ordering::SeqCst);
    }

    /// Apply (or clear) downstream bandwidth shaping mid-run.
    pub fn set_shape(&self, trace: Option<BandwidthTrace>) {
        *self.inner.shape.lock().unwrap() = trace;
    }

    pub fn shutdown(&mut self) {
        if self.inner.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<ProxyInner>) {
    let mut conn_no = 0u64;
    for conn in listener.incoming() {
        if inner.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(client) = conn else { continue };
        conn_no += 1;
        inner.stats.connections.fetch_add(1, Ordering::SeqCst);
        if inner.down.load(Ordering::SeqCst) {
            // dropped before any byte: a dial that "succeeded" against a
            // dead backend, the worst-timed crash shape
            inner.stats.refused.fetch_add(1, Ordering::SeqCst);
            continue;
        }
        let faults = inner.spec.decide(conn_no);
        let inner = inner.clone();
        let spawned = std::thread::Builder::new()
            .name("prognet-fault-conn".into())
            .stack_size(128 * 1024)
            .spawn(move || {
                let _ = forward_conn(client, &inner, faults);
            });
        drop(spawned);
    }
}

/// Pump one proxied connection: requests verbatim on a side thread,
/// responses through the fault engine.
fn forward_conn(client: TcpStream, inner: &ProxyInner, faults: ConnFaults) -> Result<()> {
    if let Some(d) = faults.delay {
        inner.stats.delayed.fetch_add(1, Ordering::SeqCst);
        inner.clock.sleep(d);
    }
    let upstream_addr = *inner.upstream.lock().unwrap();
    let up = TcpStream::connect(upstream_addr).context("fault proxy dialing upstream")?;
    client.set_nodelay(true).ok();
    up.set_nodelay(true).ok();

    // client → upstream: verbatim
    let pump_up = {
        let mut client_r = client.try_clone()?;
        let mut up_w = up.try_clone()?;
        std::thread::Builder::new()
            .name("prognet-fault-up".into())
            .stack_size(64 * 1024)
            .spawn(move || {
                let mut buf = [0u8; 4096];
                loop {
                    match client_r.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if up_w.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                let _ = up_w.shutdown(std::net::Shutdown::Write);
            })?
    };

    // upstream → client: corrupt / shape / sever
    let mut up_r = up.try_clone()?;
    let mut client_w = client.try_clone()?;
    let mut sent = 0u64;
    let mut buf = [0u8; 4096];
    let start = inner.clock.now();
    let outcome: Result<()> = loop {
        let n = match up_r.read(&mut buf) {
            Ok(0) | Err(_) => break Ok(()),
            Ok(n) => n,
        };
        let mut chunk = buf[..n].to_vec();
        let mut cut_at = chunk.len();
        if let Some(limit) = faults.sever_after {
            if sent + chunk.len() as u64 >= limit {
                cut_at = (limit.saturating_sub(sent)) as usize;
            }
        }
        for &(at, mask) in &faults.corrupt {
            if at >= sent && at < sent + cut_at as u64 {
                let i = (at - sent) as usize;
                chunk[i] ^= mask;
                inner.stats.corrupted.fetch_add(1, Ordering::SeqCst);
            }
        }
        if let Some(trace) = inner.shape.lock().unwrap().clone() {
            // piecewise-constant pacing: wait out the trace's transfer
            // time for this chunk at the current virtual offset
            let elapsed = inner.clock.now().saturating_duration_since(start);
            let dt = trace.transfer_time_from(elapsed.as_secs_f64(), cut_at as u64);
            if dt.is_finite() && dt > 0.0 {
                inner.clock.sleep(Duration::from_secs_f64(dt.min(3600.0)));
            }
        }
        if client_w.write_all(&chunk[..cut_at]).is_err() {
            break Ok(());
        }
        sent += cut_at as u64;
        if Some(sent) == faults.sever_after {
            inner.stats.severed.fetch_add(1, Ordering::SeqCst);
            break Ok(());
        }
    };
    // drop both directions; the pump thread exits on its read error
    let _ = client.shutdown(std::net::Shutdown::Both);
    let _ = up.shutdown(std::net::Shutdown::Both);
    let _ = pump_up.join();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    #[test]
    fn spec_grammar_round_trips() {
        let spec =
            FaultSpec::parse("sever:after=8000:conn=1,corrupt:at=64:mask=40,delay:ms=5,seed=7")
                .unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.rules.len(), 3);
        let f = spec.decide(1);
        assert_eq!(f.sever_after, Some(8000));
        assert_eq!(f.corrupt, vec![(64, 0x40)]);
        assert_eq!(f.delay, Some(Duration::from_millis(5)));
        let f2 = spec.decide(2);
        assert_eq!(f2.sever_after, None, "conn=1 rule must not hit conn 2");
        assert!(FaultSpec::parse("sever").is_err(), "missing after=");
        assert!(FaultSpec::parse("explode:at=1").is_err(), "unknown action");
        assert!(FaultSpec::parse("").unwrap().is_pass_through());
    }

    #[test]
    fn probability_rules_are_deterministic_in_seed_and_conn() {
        let spec = FaultSpec::parse("sever:after=100:p=0.5,seed=42").unwrap();
        let draw = |s: &FaultSpec| -> Vec<bool> {
            (1..=64).map(|c| s.decide(c).sever_after.is_some()).collect()
        };
        let picks = draw(&spec);
        assert_eq!(picks, draw(&spec), "same seed, same verdicts");
        let hit = picks.iter().filter(|&&b| b).count();
        assert!(hit > 8 && hit < 56, "p=0.5 over 64 draws, got {hit}");
        let other = FaultSpec::parse("sever:after=100:p=0.5,seed=43").unwrap();
        let differs = draw(&other) != picks;
        assert!(differs, "different seed must change some verdict");
    }

    /// One-shot upstream echo server: accepts, reads until EOF of the
    /// request direction is *not* required — it just writes `payload`
    /// and closes.
    fn payload_server(payload: Vec<u8>) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut s) = conn else { break };
                let payload = payload.clone();
                std::thread::spawn(move || {
                    let _ = s.write_all(&payload);
                });
            }
        });
        addr
    }

    fn read_all(addr: SocketAddr) -> Vec<u8> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"hi").unwrap();
        let mut got = Vec::new();
        let _ = s.read_to_end(&mut got);
        got
    }

    #[test]
    fn proxy_severs_mid_stream_and_corrupts_in_flight() {
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let up = payload_server(payload.clone());
        let spec =
            FaultSpec::parse("sever:after=6000:conn=1,corrupt:at=10:mask=ff:conn=2").unwrap();
        let mut proxy = FaultProxy::start(up, spec, Clock::real()).unwrap();

        let got1 = read_all(proxy.addr());
        assert_eq!(got1.len(), 6000, "conn 1 severed mid-stream");
        assert_eq!(&got1[..], &payload[..6000], "prefix is untouched");

        let got2 = read_all(proxy.addr());
        assert_eq!(got2.len(), payload.len(), "conn 2 full length");
        assert_eq!(got2[10], payload[10] ^ 0xff, "byte 10 flipped");
        let mut fixed = got2.clone();
        fixed[10] = payload[10];
        assert_eq!(fixed, payload, "only byte 10 differs");

        let st = proxy.stats();
        assert_eq!(st.severed.load(Ordering::SeqCst), 1);
        assert_eq!(st.corrupted.load(Ordering::SeqCst), 1);
        proxy.shutdown();
    }

    #[test]
    fn down_proxy_drops_connections_until_marked_up() {
        let up = payload_server(b"ok".to_vec());
        let mut proxy =
            FaultProxy::start(up, FaultSpec::pass_through(), Clock::real()).unwrap();
        proxy.set_down(true);
        assert!(read_all(proxy.addr()).is_empty(), "down path yields no bytes");
        proxy.set_down(false);
        assert_eq!(read_all(proxy.addr()), b"ok".to_vec());
        assert_eq!(proxy.stats().refused.load(Ordering::SeqCst), 1);
        proxy.shutdown();
    }

    #[test]
    fn upstream_swap_redirects_new_connections() {
        let a = payload_server(b"aaaa".to_vec());
        let b = payload_server(b"bbbb".to_vec());
        let mut proxy = FaultProxy::start(a, FaultSpec::pass_through(), Clock::real()).unwrap();
        assert_eq!(read_all(proxy.addr()), b"aaaa".to_vec());
        proxy.set_upstream(b);
        assert_eq!(read_all(proxy.addr()), b"bbbb".to_vec());
        proxy.shutdown();
    }
}
