//! Virtual-time link model (no sleeping) — the basis of the Table I /
//! Fig 4 timeline computations and of the user-study simulator.

/// A link configuration (paper speeds: 0.1–2.5 MB/s).

#![forbid(unsafe_code)]
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    /// bandwidth in bytes/second
    pub bytes_per_sec: f64,
    /// one-way latency in seconds (applied once per transfer)
    pub latency_s: f64,
}

impl LinkSpec {
    pub fn mbps(mb_per_sec: f64) -> Self {
        Self {
            bytes_per_sec: mb_per_sec * 1024.0 * 1024.0,
            latency_s: 0.0,
        }
    }

    pub fn with_latency(mut self, latency_s: f64) -> Self {
        self.latency_s = latency_s;
        self
    }

    /// Seconds to deliver `bytes` on an idle link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_sec
    }
}

/// Virtual-time cursor over a link: tracks when each queued byte range
/// finishes arriving. Deterministic and instantaneous to evaluate.
#[derive(Debug, Clone)]
pub struct Link {
    spec: LinkSpec,
    /// virtual time at which the link becomes free
    free_at: f64,
    delivered_bytes: u64,
}

impl Link {
    pub fn new(spec: LinkSpec) -> Self {
        Self {
            spec,
            free_at: spec.latency_s,
            delivered_bytes: 0,
        }
    }

    pub fn spec(&self) -> LinkSpec {
        self.spec
    }

    /// Queue `bytes` for transmission; returns the virtual completion time.
    pub fn send(&mut self, bytes: u64) -> f64 {
        self.free_at += bytes as f64 / self.spec.bytes_per_sec;
        self.delivered_bytes += bytes;
        self.free_at
    }

    /// Virtual time when everything queued so far has arrived.
    pub fn now_complete(&self) -> f64 {
        self.free_at
    }

    pub fn delivered_bytes(&self) -> u64 {
        self.delivered_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_matches_rate() {
        let l = LinkSpec::mbps(1.0);
        let t = l.transfer_time(7 * 1024 * 1024);
        assert!((t - 7.0).abs() < 1e-9);
    }

    #[test]
    fn latency_applied_once() {
        let l = LinkSpec::mbps(2.0).with_latency(0.05);
        assert!((l.transfer_time(2 * 1024 * 1024) - 1.05).abs() < 1e-9);
    }

    #[test]
    fn sequential_sends_accumulate() {
        let mut link = Link::new(LinkSpec::mbps(1.0));
        let t1 = link.send(512 * 1024);
        let t2 = link.send(512 * 1024);
        assert!((t1 - 0.5).abs() < 1e-9);
        assert!((t2 - 1.0).abs() < 1e-9);
        assert_eq!(link.delivered_bytes(), 1024 * 1024);
    }

    #[test]
    fn paper_configuration_times() {
        // MobileNetV2 7.1 MB at 1 MB/s ≈ 7.1 s of pure transmission —
        // the paper's Table I singleton times are dominated by this.
        let spec = LinkSpec::mbps(1.0);
        let t = spec.transfer_time((7.1 * 1024.0 * 1024.0) as u64);
        assert!((t - 7.1).abs() < 0.01);
    }
}
