//! Real-time token-bucket shaping for socket writes.
//!
//! The bucket math lives in [`TokenBucket`] and is shared by two
//! consumers with opposite blocking disciplines:
//!
//! - [`ThrottledWriter`] — a `Write` adapter that *sleeps* until the
//!   schedule catches up (the classic blocking write path);
//! - the fleet reactor (`fleet::conn`) — which never sleeps: it asks the
//!   bucket for the current byte budget and, when the budget is empty,
//!   for the instant it refills, and folds that into its poll timeout.
//!   That is how thousands of paced connections share a handful of
//!   event-loop threads.
//!
//! One-way latency is a property of the blocking writer only (it sleeps
//! once before the first byte); the bucket itself is pure rate.

#![forbid(unsafe_code)]

use std::io::{self, Write};
use std::time::{Duration, Instant};

use super::link::LinkSpec;
use crate::util::sync::{clock, Clock};

/// Maximum chunk written between pacing checks.
const CHUNK: usize = 16 * 1024;

/// Pure token-bucket pacing state for one shaped stream: `sent` bytes
/// are due at `sent / bytes_per_sec` seconds after [`TokenBucket::restart`],
/// and `burst` bytes may run ahead of that schedule (0 = exact pacing).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    bytes_per_sec: f64,
    start: Instant,
    sent: u64,
    burst: f64,
}

impl TokenBucket {
    /// Bucket with an exact schedule (no burst) — what the sleeping
    /// writer uses.
    pub fn new(spec: LinkSpec) -> Self {
        Self::with_burst(spec, 0)
    }

    /// Bucket allowed to run `burst` bytes ahead of the schedule — what
    /// the reactor uses so each poll wakeup can write a full chunk.
    pub fn with_burst(spec: LinkSpec, burst: usize) -> Self {
        Self::with_burst_at(spec, burst, clock::now())
    }

    /// Like [`TokenBucket::with_burst`], with an explicit schedule start —
    /// callers running on an injected [`Clock`](crate::util::sync::Clock)
    /// pass their own reading so the whole schedule lives on that
    /// timeline.
    pub fn with_burst_at(spec: LinkSpec, burst: usize, now: Instant) -> Self {
        Self {
            bytes_per_sec: spec.bytes_per_sec,
            start: now,
            sent: 0,
            burst: burst as f64,
        }
    }

    /// Bytes accounted so far.
    pub fn sent(&self) -> u64 {
        self.sent
    }

    /// Account `n` bytes against the schedule.
    pub fn on_sent(&mut self, n: usize) {
        self.sent += n as u64;
    }

    /// Restart the schedule clock at `now` (used by the writer after its
    /// one-off latency sleep, so latency is not charged against rate).
    pub fn restart(&mut self, now: Instant) {
        self.start = now;
    }

    /// Bytes that may be written right now without getting ahead of the
    /// schedule (plus the configured burst).
    pub fn budget(&self, now: Instant) -> usize {
        let elapsed = now.saturating_duration_since(self.start).as_secs_f64();
        let allowed = elapsed * self.bytes_per_sec + self.burst - self.sent as f64;
        if allowed <= 0.0 {
            0
        } else {
            allowed as usize
        }
    }

    /// How long until at least one byte of budget exists; `None` when
    /// bytes may be written immediately. Callers that cannot sleep fold
    /// this into their poll timeout; the fleet reactor also compares it
    /// against the I/O deadline to spot rates so low they would pin a
    /// connection forever. Clamped to one hour so the result can always
    /// be added to an `Instant` without overflow, even for degenerate
    /// (client-supplied) rates.
    pub fn ready_in(&self, now: Instant) -> Option<Duration> {
        if self.budget(now) > 0 {
            return None;
        }
        // time at which `allowed >= 1` byte: (sent + 1 - burst) / rate
        let deficit = (self.sent as f64 + 1.0 - self.burst).max(0.0);
        let due_s = (deficit / self.bytes_per_sec).min(3600.0);
        let due = Duration::from_secs_f64(due_s.max(0.0));
        let elapsed = now.saturating_duration_since(self.start);
        Some(due.saturating_sub(elapsed).max(Duration::from_micros(1)))
    }
}

/// A `Write` adapter that paces bytes at `spec.bytes_per_sec` by
/// sleeping on the current thread.
pub struct ThrottledWriter<W: Write> {
    inner: W,
    bucket: TokenBucket,
    first_write_latency: Option<Duration>,
    clock: Clock,
}

impl<W: Write> ThrottledWriter<W> {
    pub fn new(inner: W, spec: LinkSpec) -> Self {
        Self::with_clock(inner, spec, Clock::real())
    }

    /// Writer paced against an injected time source. With
    /// [`Clock::manual`] the pacing math runs unchanged but "sleeping"
    /// advances the clock instead of blocking, so shaping tests assert
    /// exact virtual timelines at full speed.
    pub fn with_clock(inner: W, spec: LinkSpec, clock: Clock) -> Self {
        let mut bucket = TokenBucket::new(spec);
        bucket.restart(clock.now());
        Self {
            inner,
            bucket,
            first_write_latency: if spec.latency_s > 0.0 {
                Some(Duration::from_secs_f64(spec.latency_s))
            } else {
                None
            },
            clock,
        }
    }

    /// Bytes sent so far.
    pub fn sent(&self) -> u64 {
        self.bucket.sent()
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

impl<W: Write> Write for ThrottledWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if let Some(lat) = self.first_write_latency.take() {
            self.clock.sleep(lat);
            self.bucket.restart(self.clock.now());
        }
        let n = buf.len().min(CHUNK);
        let written = self.inner.write(&buf[..n])?;
        self.bucket.on_sent(written);
        // Sleep until the virtual schedule catches up with what we sent.
        if let Some(wait) = self.bucket.ready_in(self.clock.now()) {
            self.clock.sleep(wait);
        }
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pacing_is_close_to_rate() {
        // 200 KB at 1 MB/s should take ~0.2 s (±30% slack for CI noise).
        let spec = LinkSpec::mbps(1.0);
        let mut w = ThrottledWriter::new(Vec::new(), spec);
        let data = vec![0u8; 200 * 1024];
        let t0 = Instant::now();
        w.write_all(&data).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let expect = 200.0 / 1024.0;
        assert!(
            dt > expect * 0.7 && dt < expect * 1.6,
            "took {dt:.3}s, expected ~{expect:.3}s"
        );
        assert_eq!(w.sent(), data.len() as u64);
        assert_eq!(w.into_inner().len(), data.len());
    }

    #[test]
    fn fast_link_is_nearly_instant() {
        let spec = LinkSpec::mbps(10_000.0);
        let mut w = ThrottledWriter::new(Vec::new(), spec);
        let t0 = Instant::now();
        w.write_all(&vec![0u8; 1024 * 1024]).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.5);
    }

    #[test]
    fn latency_delays_first_byte() {
        let spec = LinkSpec::mbps(10_000.0).with_latency(0.05);
        let mut w = ThrottledWriter::new(Vec::new(), spec);
        let t0 = Instant::now();
        w.write_all(&[1, 2, 3]).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.045);
    }

    #[test]
    fn manual_clock_pacing_runs_on_the_virtual_timeline() {
        // 10 MB at 1 MB/s = ~10 virtual seconds, asserted exactly-ish,
        // while wall time stays near zero: "sleeps" advance the clock.
        let clock = Clock::manual();
        let mut w = ThrottledWriter::with_clock(Vec::new(), LinkSpec::mbps(1.0), clock.clone());
        let t0 = clock.now();
        let wall = Instant::now();
        w.write_all(&vec![0u8; 10 * 1024 * 1024]).unwrap();
        let virt = clock.now() - t0;
        assert!(
            virt >= Duration::from_secs_f64(9.5) && virt <= Duration::from_secs_f64(11.0),
            "virtual elapsed {virt:?}, expected ~10s"
        );
        assert!(wall.elapsed() < Duration::from_secs(5), "must not really sleep");
    }

    #[test]
    fn manual_clock_charges_latency_before_first_byte() {
        let clock = Clock::manual();
        let spec = LinkSpec::mbps(1000.0).with_latency(0.25);
        let mut w = ThrottledWriter::with_clock(Vec::new(), spec, clock.clone());
        let t0 = clock.now();
        w.write_all(&[1, 2, 3]).unwrap();
        assert!(clock.now() - t0 >= Duration::from_millis(250));
    }

    #[test]
    fn bucket_budget_tracks_schedule() {
        let mut b = TokenBucket::with_burst(LinkSpec::mbps(1.0), 1024);
        let t0 = Instant::now();
        // fresh bucket: the burst is immediately available
        let first = b.budget(t0);
        assert!(first >= 1024, "burst available at t0, got {first}");
        b.on_sent(first);
        // budget exhausted → not ready, and the refill wait is sane
        assert_eq!(b.budget(t0), 0);
        let wait = b.ready_in(t0).expect("budget exhausted");
        assert!(wait <= Duration::from_secs(1), "wait {wait:?}");
        // after the advertised wait the budget is positive again
        let later = t0 + wait + Duration::from_millis(2);
        assert!(b.budget(later) > 0);
        assert!(b.ready_in(later).is_none());
    }

    #[test]
    fn zero_burst_bucket_accrues_with_time() {
        let b = TokenBucket::new(LinkSpec::mbps(1.0));
        let t0 = Instant::now();
        // exact schedule: budget grows with elapsed time even before any send
        let later = t0 + Duration::from_millis(100);
        let budget = b.budget(later);
        assert!(
            budget >= 90 * 1024 && budget <= 120 * 1024,
            "0.1s at 1 MB/s ≈ 102 KB, got {budget}"
        );
    }
}
