//! Real-time token-bucket shaping for socket writes.
//!
//! The server wraps each client connection in a [`ThrottledWriter`] so an
//! end-to-end run over loopback experiences the configured bandwidth.
//! Token-bucket with a small burst keeps pacing smooth at low rates
//! without busy-waiting.

use std::io::{self, Write};
use std::time::{Duration, Instant};

use super::link::LinkSpec;

/// Maximum chunk written between pacing checks.
const CHUNK: usize = 16 * 1024;

/// A `Write` adapter that paces bytes at `spec.bytes_per_sec`.
pub struct ThrottledWriter<W: Write> {
    inner: W,
    bytes_per_sec: f64,
    start: Instant,
    sent: u64,
    first_write_latency: Option<Duration>,
}

impl<W: Write> ThrottledWriter<W> {
    pub fn new(inner: W, spec: LinkSpec) -> Self {
        Self {
            inner,
            bytes_per_sec: spec.bytes_per_sec,
            start: Instant::now(),
            sent: 0,
            first_write_latency: if spec.latency_s > 0.0 {
                Some(Duration::from_secs_f64(spec.latency_s))
            } else {
                None
            },
        }
    }

    /// Bytes sent so far.
    pub fn sent(&self) -> u64 {
        self.sent
    }

    pub fn into_inner(self) -> W {
        self.inner
    }

    fn pace(&mut self) {
        // Sleep until the virtual schedule catches up with what we sent.
        let due = Duration::from_secs_f64(self.sent as f64 / self.bytes_per_sec);
        let elapsed = self.start.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
    }
}

impl<W: Write> Write for ThrottledWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if let Some(lat) = self.first_write_latency.take() {
            std::thread::sleep(lat);
            self.start = Instant::now();
        }
        let n = buf.len().min(CHUNK);
        let written = self.inner.write(&buf[..n])?;
        self.sent += written as u64;
        self.pace();
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pacing_is_close_to_rate() {
        // 200 KB at 1 MB/s should take ~0.2 s (±30% slack for CI noise).
        let spec = LinkSpec::mbps(1.0);
        let mut w = ThrottledWriter::new(Vec::new(), spec);
        let data = vec![0u8; 200 * 1024];
        let t0 = Instant::now();
        w.write_all(&data).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let expect = 200.0 / 1024.0;
        assert!(
            dt > expect * 0.7 && dt < expect * 1.6,
            "took {dt:.3}s, expected ~{expect:.3}s"
        );
        assert_eq!(w.sent(), data.len() as u64);
        assert_eq!(w.into_inner().len(), data.len());
    }

    #[test]
    fn fast_link_is_nearly_instant() {
        let spec = LinkSpec::mbps(10_000.0);
        let mut w = ThrottledWriter::new(Vec::new(), spec);
        let t0 = Instant::now();
        w.write_all(&vec![0u8; 1024 * 1024]).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.5);
    }

    #[test]
    fn latency_delays_first_byte() {
        let spec = LinkSpec::mbps(10_000.0).with_latency(0.05);
        let mut w = ThrottledWriter::new(Vec::new(), spec);
        let t0 = Instant::now();
        w.write_all(&[1, 2, 3]).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.045);
    }
}
