//! The progressive client — the "user device" half of Fig 1.
//!
//! Pipeline: bytes arrive from the socket ([`downloader`]) → the frame
//! parser yields fragments → the [`assembler`] OR-accumulates them into
//! per-tensor code buffers (Eq. 4) → on each completed stage the weights
//! are dequantized (Eq. 5), published into a hot-swappable
//! [`ApproxModel`](crate::runtime::ApproxModel), and (optionally)
//! inferred.
//!
//! The single entry point is [`session::ProgressiveSession`]: a builder
//! that subsumes fetch, resume, cache and multiplex behind one typed
//! event stream (`StageComplete` → `ModelReady` → `Inference` …
//! `Finished`), supporting both execution modes of Fig 4 — **serial**
//! ("w/o concurrent": reconstruction + inference block the download) and
//! **concurrent** (§III-C: a separate inference thread overlaps with the
//! ongoing transfer — the paper's key systems trick that makes
//! progressive inference free). Single-model blocking fetches are
//! `builder(model) … .start()?.run()?`; interleaved multi-model delivery
//! is `multiplex() … .add_model(req, priority) … .start()?.run()?`.

#![forbid(unsafe_code)]

pub mod assembler;
pub mod cache;
pub mod downloader;
pub mod session;

pub use assembler::Assembler;
pub use cache::{FetchOutcome, ModelCache};
pub use downloader::Downloader;
pub use session::{
    ExecMode, InferencePolicy, ProgressiveSession, ResumeSource, SessionBuilder, SessionEvent,
    SessionOutcome, SessionReport, SessionSummary, StageResult,
};
