//! The progressive client — the "user device" half of Fig 1.
//!
//! Pipeline: bytes arrive from the socket ([`downloader`]) → the frame
//! parser yields fragments → the [`assembler`] OR-accumulates them into
//! per-tensor code buffers (Eq. 4) → on each completed stage the weights
//! are dequantized (Eq. 5), published into a hot-swappable
//! [`ApproxModel`](crate::runtime::ApproxModel), and (optionally)
//! inferred.
//!
//! The single entry point is [`session::ProgressiveSession`]: a builder
//! that subsumes fetch, resume, cache and multiplex behind one typed
//! event stream (`StageComplete` → `ModelReady` → `Inference` …
//! `Finished`), supporting both execution modes of Fig 4 — **serial**
//! ("w/o concurrent": reconstruction + inference block the download) and
//! **concurrent** (§III-C: a separate inference thread overlaps with the
//! ongoing transfer — the paper's key systems trick that makes
//! progressive inference free). The pre-session blocking façades,
//! [`progressive::ProgressiveClient`] and [`multiplex::MultiplexClient`],
//! survive as thin deprecated wrappers over the session driver.

#![forbid(unsafe_code)]

pub mod assembler;
pub mod cache;
pub mod downloader;
pub mod multiplex;
pub mod progressive;
pub mod session;

pub use assembler::Assembler;
pub use cache::{FetchOutcome, ModelCache};
pub use downloader::Downloader;
#[allow(deprecated)]
pub use multiplex::MultiplexClient;
pub use multiplex::{MultiplexModel, MultiplexOutcome};
#[allow(deprecated)]
pub use progressive::ProgressiveClient;
pub use progressive::ProgressiveOptions;
pub use session::{
    ExecMode, InferencePolicy, ProgressiveSession, ResumeSource, SessionBuilder, SessionEvent,
    SessionOutcome, SessionReport, SessionSummary, StageResult,
};
