//! The progressive client — the "user device" half of Fig 1.
//!
//! Pipeline: bytes arrive from the socket ([`downloader`]) → the frame
//! parser yields fragments → the [`assembler`] OR-accumulates them into
//! per-tensor code buffers (Eq. 4) → on each completed stage the weights
//! are dequantized (Eq. 5) and the approximate model is inferred.
//!
//! [`progressive::ProgressiveClient`] supports both execution modes of
//! Fig 4: **serial** ("w/o concurrent": reconstruction + inference block
//! the download) and **concurrent** (§III-C: a separate inference thread
//! overlaps with the ongoing transfer — the paper's key systems trick
//! that makes progressive inference free).

pub mod assembler;
pub mod cache;
pub mod downloader;
pub mod multiplex;
pub mod progressive;

pub use assembler::Assembler;
pub use cache::{FetchOutcome, ModelCache};
pub use downloader::Downloader;
pub use multiplex::{MultiplexClient, MultiplexModel, MultiplexOutcome};
pub use progressive::{
    ExecMode, InferencePolicy, ProgressiveClient, ProgressiveOptions, SessionOutcome, StageResult,
};
