//! `ProgressiveSession` — the unified, event-driven client surface.
//!
//! One builder subsumes what used to be four separate entry points
//! (progressive fetch, resume, cache, multiplex): callers drive a typed
//! [`SessionEvent`] stream — blocking iteration via
//! [`ProgressiveSession::next_event`] / [`ProgressiveSession::events`],
//! or non-blocking polling via [`ProgressiveSession::try_event`] — and,
//! when a runtime is bound, get an
//! [`ApproxModel`](crate::runtime::ApproxModel) handle that atomically
//! upgrades in place as stages complete. That handle is what makes
//! mid-download serving compose: hand it to
//! [`Router::bind`](crate::coordinator::Router::bind) and the
//! coordinator answers inference requests with the stage-*k* model while
//! stages *k+1…* are still streaming.
//!
//! Event order per completed stage `k`:
//! `StageComplete(k)` → `ModelReady(k)` (weights published) →
//! `Inference(k)` (if a workload is configured), with `Resumed` markers
//! wherever the transfer continued from a cache prefix or a reconnect,
//! and exactly one final `Finished`. Stage indices are strictly
//! increasing and never duplicated, including across resumes — the
//! invariants `tests/session_events.rs` property-checks.
//!
//! Layer-annotated (`LayerMajor`) containers additionally emit
//! [`SessionEvent::LayerReady`] as each layer finishes a stage —
//! interleaved *ahead* of that stage's `StageComplete`, strictly
//! increasing and duplicate-free per layer — and an attached
//! [`LayerGate`] ([`SessionBuilder::layer_gate`]) receives each layer's
//! dequantized weights the moment they land, which is what lets a
//! pipelined executor
//! ([`execute_streaming`](crate::runtime::CompiledModel::execute_streaming))
//! start inference before stage 0 has fully arrived.
//!
//! ```
//! use std::sync::Arc;
//! use prognet::client::session::{ProgressiveSession, SessionEvent};
//! use prognet::runtime::{Engine, ModelSession};
//! use prognet::server::service::ServerConfig;
//! use prognet::server::{Repository, Server};
//!
//! # fn main() -> prognet::Result<()> {
//! let reg = prognet::testutil::fixture::executable_models("doc-session")?;
//! let manifest = reg.get("dense3")?.clone();
//! let server = Server::start(
//!     "127.0.0.1:0",
//!     Arc::new(Repository::new(reg)),
//!     ServerConfig::default(),
//! )?;
//! let session = Arc::new(ModelSession::load(&Engine::reference(), &manifest)?);
//! let images = vec![0.5f32; manifest.input_numel()];
//!
//! let handle = ProgressiveSession::builder("dense3")
//!     .addr(server.addr())
//!     .runtime("dense3", session)
//!     .workload(images, 1)
//!     .start()?;
//! // the hot-swappable model is available immediately …
//! let approx = handle.approx_model().expect("runtime bound").clone();
//! let mut stages = 0;
//! while let Some(ev) = handle.next_event() {
//!     if let SessionEvent::StageComplete { stage, .. } = ev {
//!         stages = stage + 1;
//!     }
//! }
//! assert_eq!(stages, 8);
//! // … and has been upgraded in place to full precision
//! assert_eq!(approx.cum_bits(), 16);
//! let report = handle.finish()?;
//! assert_eq!(report.results.len(), 8);
//! # Ok(()) }
//! ```

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use crate::util::sync::clock;
use crate::util::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::assembler::Assembler;
use super::cache::ModelCache;
use super::downloader::{Downloader, TimedEvent};
use crate::coordinator::scheduler::{interleave_stages, InterleaveModel};
use crate::format::header::PnetManifest;
use crate::format::{FrameParser, ParserEvent, PnetReader};
use crate::metrics::{EventKind, Timeline};
use crate::obs::{self, TraceCtx};
use crate::quant::Schedule;
use crate::runtime::stream::LayerGate;
use crate::runtime::{ApproxModel, InferOutput, ModelSession};
use crate::server::proto::FetchRequest;
use crate::server::service::request_on;
use crate::util::pool::BoundedQueue;
use crate::util::retry::{Retry, RetryPolicy};
use crate::util::sync::Clock;

/// Serial (paper "w/o concurrent") vs concurrent (§III-C) execution.
///
/// Serial blocks the socket while each stage reconstructs and infers (a
/// small `SO_RCVBUF` makes the sender actually stall); concurrent keeps
/// the transfer flowing while a worker assembles and infers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    Serial,
    Concurrent,
}

/// Which completed stages trigger an inference pass over the workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferencePolicy {
    /// Infer at every completed stage (the paper's 2→4→…→16 run).
    EveryStage,
    /// Skip to the newest complete stage when inference lags the link.
    LatestOnly,
    /// Only infer once the final stage arrived (singleton behaviour).
    FinalOnly,
}

/// One intermediate (or final) inference result.
#[derive(Debug, Clone)]
pub struct StageResult {
    pub stage: usize,
    pub cum_bits: u32,
    pub output: InferOutput,
    /// seconds since fetch start when the stage's bytes had arrived
    pub t_transfer_done: f64,
    /// seconds since fetch start when this result became visible
    pub t_output_ready: f64,
}

/// Outcome of a full progressive session (the pre-event-stream shape,
/// still returned by the deprecated wrappers).
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    pub results: Vec<StageResult>,
    /// wall time until the last byte arrived
    pub t_transfer_complete: f64,
    /// wall time until the last output was shown (the paper's "total
    /// execution time")
    pub t_total: f64,
    pub bytes: u64,
    pub timeline: Timeline,
}

/// Where a [`SessionEvent::Resumed`] continuation came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResumeSource {
    /// Stages replayed from the on-disk partial-download cache; the
    /// network fetch starts at the cached stage boundary.
    Cache,
    /// The connection dropped and the session reconnected at the last
    /// complete stage boundary.
    Reconnect,
}

/// Transfer/serving totals reported by [`SessionEvent::Finished`] and
/// [`SessionReport::summary`].
#[derive(Debug, Clone)]
pub struct SessionSummary {
    /// wall time until the last byte arrived (0 for a pure cache replay)
    pub t_transfer_complete: f64,
    /// wall time until the last output was shown
    pub t_total: f64,
    /// body bytes received over the network
    pub bytes: u64,
    /// resumes performed (cache prefix + reconnects)
    pub resumed: usize,
    /// true when the whole container was replayed from the local cache
    pub cache_hit: bool,
}

/// Typed events of a running session, in delivery order.
#[derive(Debug, Clone)]
pub enum SessionEvent {
    /// All fragments of `stage` arrived and were absorbed.
    StageComplete {
        model: String,
        stage: usize,
        /// cumulative bits after this stage
        cum_bits: u32,
        /// seconds since session start
        t: f64,
    },
    /// The stage's reconstruction was published: the session's
    /// [`ApproxModel`](crate::runtime::ApproxModel) now serves these
    /// weights. Never precedes the matching `StageComplete`.
    ModelReady {
        model: String,
        stage: usize,
        cum_bits: u32,
        /// the handle's publish counter after the upgrade
        version: u64,
        t: f64,
    },
    /// Every tensor of `layer` has absorbed `stage`'s bit-planes: the
    /// layer is executable at `cum_bits` precision while later layers of
    /// the same stage are still in flight (`LayerMajor` containers only —
    /// unannotated containers never produce these). For each stage `s`,
    /// every `LayerReady { stage: s, .. }` precedes that stage's
    /// `StageComplete`; per layer, `stage` is strictly increasing and
    /// duplicate-free, including across cache resumes and reconnects
    /// (re-delivered fragments never re-emit). When a streaming gate is
    /// attached ([`SessionBuilder::layer_gate`]), the layer's dequantized
    /// weights were published into the gate just before this event.
    LayerReady {
        model: String,
        layer: usize,
        /// stage this layer just completed
        stage: usize,
        /// cumulative bits of the layer's tensors after `stage`
        cum_bits: u32,
        /// seconds since session start
        t: f64,
    },
    /// An inference pass over the configured workload finished.
    Inference { model: String, result: StageResult },
    /// The transfer continued from a cache prefix or a reconnect; no
    /// stage event is ever re-emitted after a resume.
    Resumed {
        model: String,
        /// first stage the continued transfer delivers
        stage: usize,
        /// 1-based resume counter within this session
        attempt: usize,
        source: ResumeSource,
        /// jittered backoff slept before this reconnect dial, per the
        /// session's [`RetryPolicy`] ([`Duration::ZERO`] for cache
        /// resumes, which never sleep) — surfaced so tests can assert
        /// the exact retry schedule via [`RetryPolicy::preview`]
        backoff: Duration,
    },
    /// The session is done; always the last event.
    Finished(SessionSummary),
}

/// Everything the driver hands back once the event stream closes.
pub struct SessionReport {
    /// Per-stage inference results (empty without a workload).
    pub results: Vec<StageResult>,
    /// Final assemblers by model name (codes + last reconstruction).
    pub assemblers: HashMap<String, Assembler>,
    /// Transfer/reconstruct/infer timeline (single-model sessions).
    pub timeline: Timeline,
    /// Totals, identical to the `Finished` event's payload.
    pub summary: SessionSummary,
    /// Wire requests issued (1 + reconnects, or one per stage window for
    /// multiplexed sessions).
    pub requests: usize,
    /// Executed (model, stage) delivery order.
    pub order: Vec<(String, usize)>,
}

impl SessionReport {
    /// The final assembler of `model`, if the session completed it.
    pub fn assembler(&self, model: &str) -> Option<&Assembler> {
        self.assemblers.get(model)
    }

    /// Collapse into the legacy [`SessionOutcome`] shape.
    pub fn into_outcome(self) -> SessionOutcome {
        SessionOutcome {
            results: self.results,
            t_transfer_complete: self.summary.t_transfer_complete,
            t_total: self.summary.t_total,
            bytes: self.summary.bytes,
            timeline: self.timeline,
        }
    }
}

/// One model of a session (multiplexed sessions carry several).
#[derive(Debug, Clone)]
struct ModelSpec {
    request: FetchRequest,
    /// relative bandwidth share for multiplexed delivery (> 0)
    priority: f64,
}

#[derive(Clone)]
struct Workload {
    images: Vec<f32>,
    n: usize,
}

/// Builder for a [`ProgressiveSession`]. Construct via
/// [`ProgressiveSession::builder`] (single model) or
/// [`ProgressiveSession::multiplex`] (several models, one connection).
pub struct SessionBuilder {
    addr: Option<SocketAddr>,
    specs: Vec<ModelSpec>,
    mode: ExecMode,
    policy: InferencePolicy,
    retry: RetryPolicy,
    cache_dir: Option<PathBuf>,
    runtimes: HashMap<String, Arc<ModelSession>>,
    workload: Option<Workload>,
    /// applied to every spec at `start()`, so setter order doesn't matter
    speed_override: Option<f64>,
    schedule_override: Option<Schedule>,
    /// stage-interleaved delivery over one keep-alive connection — set by
    /// [`ProgressiveSession::multiplex`], honoured even for one model so
    /// the wrapper keeps its per-stage request accounting
    multiplex: bool,
    layer_gate: Option<Arc<LayerGate>>,
}

impl SessionBuilder {
    fn new(multiplex: bool) -> Self {
        Self {
            addr: None,
            specs: Vec::new(),
            mode: ExecMode::Concurrent,
            policy: InferencePolicy::EveryStage,
            retry: RetryPolicy::default(),
            cache_dir: None,
            runtimes: HashMap::new(),
            workload: None,
            speed_override: None,
            schedule_override: None,
            multiplex,
            layer_gate: None,
        }
    }

    /// Server address (required).
    pub fn addr(mut self, addr: SocketAddr) -> Self {
        self.addr = Some(addr);
        self
    }

    /// Replace the (single) model's fetch request wholesale — schedule,
    /// speed override, etc. Panics on multiplexed builders; use
    /// [`SessionBuilder::add_model`] there.
    pub fn request(mut self, request: FetchRequest) -> Self {
        assert_eq!(
            self.specs.len(),
            1,
            "request() configures a single-model session"
        );
        self.specs[0].request = request;
        self
    }

    /// Add one model to a multiplexed session.
    pub fn add_model(mut self, request: FetchRequest, priority: f64) -> Self {
        self.specs.push(ModelSpec { request, priority });
        self
    }

    /// Serial vs concurrent execution (default concurrent).
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Which stages run workload inference (default every stage).
    pub fn policy(mut self, policy: InferencePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Server-side bandwidth shaping override, MB/s. Applies to every
    /// model of the session at `start()`, regardless of whether the
    /// model was added before or after this call.
    pub fn speed_mbps(mut self, mbps: f64) -> Self {
        self.speed_override = Some(mbps);
        self
    }

    /// Progressive schedule override. Applies to every model of the
    /// session at `start()`, regardless of call order.
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule_override = Some(schedule);
        self
    }

    /// On a dropped connection, reconnect at the last complete stage
    /// boundary up to this many times (default 2; 0 = fail fast).
    /// Single-model sessions only — a multiplexed session fails fast
    /// (see [`ProgressiveSession::multiplex`]). Reconnect dials are
    /// spaced by the session's [`RetryPolicy`] (jittered exponential
    /// backoff); use [`SessionBuilder::retry_policy`] to reshape it.
    pub fn resume_retries(mut self, retries: usize) -> Self {
        let attempts = u32::try_from(retries).unwrap_or(u32::MAX - 1).saturating_add(1);
        self.retry = self.retry.attempts(attempts);
        self
    }

    /// Replace the reconnect backoff policy wholesale (attempts, base
    /// delay, factor, jitter, deadline budget). The policy's attempt
    /// count is 1 + the number of resumes — `resume_retries(n)` is sugar
    /// for `attempts(n + 1)` on the current policy. The jitter stream is
    /// salted with the model name, so the schedule is deterministic per
    /// model and assertable via [`RetryPolicy::preview`].
    pub fn retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// Enable the on-disk cache: completed containers replay without the
    /// network, partial downloads persist at every stage boundary, and a
    /// later session resumes from the last cached complete stage.
    /// Single-model sessions only.
    pub fn cache_dir<P: Into<PathBuf>>(mut self, dir: P) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Bind a compiled runtime session for `model`: each completed stage
    /// is reconstructed and published into an
    /// [`ApproxModel`](crate::runtime::ApproxModel) (→ `ModelReady`
    /// events and mid-download serving).
    pub fn runtime(mut self, model: &str, session: Arc<ModelSession>) -> Self {
        self.runtimes.insert(model.to_string(), session);
        self
    }

    /// Run inference over `images` (`n` samples) per the policy at each
    /// completed stage (→ `Inference` events). Requires a bound runtime;
    /// single-model sessions only.
    pub fn workload(mut self, images: Vec<f32>, n: usize) -> Self {
        self.workload = Some(Workload { images, n });
        self
    }

    /// Attach a streaming [`LayerGate`]: every per-layer completion
    /// publishes the layer's dequantized weight segment (plus its arrival
    /// time) into the gate just before the matching
    /// [`SessionEvent::LayerReady`], so a pipelined executor
    /// ([`execute_streaming`](crate::runtime::CompiledModel::execute_streaming))
    /// on another thread overlaps inference with the ongoing download.
    /// Forces eager (per-fragment) dequantization. The driver closes the
    /// gate on every exit path — success, error, or panic — releasing any
    /// blocked executor. Requires a layer-annotated container;
    /// single-model sessions only.
    pub fn layer_gate(mut self, gate: Arc<LayerGate>) -> Self {
        self.layer_gate = Some(gate);
        self
    }

    /// Spawn the session driver and return the live handle.
    pub fn start(mut self) -> Result<ProgressiveSession> {
        anyhow::ensure!(!self.specs.is_empty(), "no models requested");
        // apply session-wide overrides now, so setter order is irrelevant
        for s in &mut self.specs {
            if let Some(mbps) = self.speed_override {
                s.request = s.request.clone().with_speed(mbps);
            }
            if let Some(sched) = &self.schedule_override {
                s.request = s.request.clone().with_schedule(sched.clone());
            }
        }
        let addr = self
            .addr
            .context("server address not set (SessionBuilder::addr)")?;
        let mut seen = std::collections::HashSet::new();
        for s in &self.specs {
            anyhow::ensure!(
                seen.insert(s.request.model.clone()),
                "duplicate model '{}' in session",
                s.request.model
            );
            anyhow::ensure!(
                s.request.offset == 0,
                "sessions resume by stage range, not byte offset"
            );
        }
        anyhow::ensure!(
            self.multiplex || self.specs.len() == 1,
            "use ProgressiveSession::multiplex() for multi-model sessions"
        );
        if self.workload.is_some() {
            anyhow::ensure!(
                !self.multiplex,
                "a per-stage inference workload requires a single-model session"
            );
            let m = &self.specs[0].request.model;
            anyhow::ensure!(
                self.runtimes.contains_key(m),
                "workload set but no runtime bound for '{m}' (SessionBuilder::runtime)"
            );
        }
        if self.layer_gate.is_some() {
            anyhow::ensure!(
                !self.multiplex,
                "a streaming layer gate requires a single-model session"
            );
        }
        if self.cache_dir.is_some() {
            anyhow::ensure!(
                !self.multiplex,
                "the download cache supports single-model sessions"
            );
            anyhow::ensure!(
                self.specs[0].request.stages.is_none(),
                "the download cache stores whole containers; drop the stage range"
            );
        }

        let mut approx: HashMap<String, ApproxModel> = HashMap::new();
        for spec in &self.specs {
            if let Some(sess) = self.runtimes.get(&spec.request.model) {
                approx.insert(spec.request.model.clone(), ApproxModel::new(sess.clone()));
            }
        }

        let events: BoundedQueue<SessionEvent> = BoundedQueue::new(1024);
        let q = events.clone();
        let approx2 = approx.clone();
        let gate = self.layer_gate.clone();
        let cfg = DriverConfig {
            addr,
            specs: self.specs,
            mode: self.mode,
            policy: self.policy,
            retry: self.retry,
            cache_dir: self.cache_dir,
            workload: self.workload,
            multiplex: self.multiplex,
            layer_gate: self.layer_gate,
        };
        let driver = std::thread::Builder::new()
            .name("prognet-session".into())
            .spawn(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    drive(cfg, &q, &approx2)
                }));
                // always close the stream — also on error/panic — or the
                // consumer would block forever on next_event(); same for
                // the streaming gate and its blocked executor
                if let Some(g) = &gate {
                    g.close();
                }
                q.close();
                match out {
                    Ok(res) => res,
                    Err(_) => Err(anyhow::anyhow!("session driver panicked")),
                }
            })
            .expect("spawn session driver");
        Ok(ProgressiveSession {
            events,
            approx,
            driver: Some(driver),
        })
    }
}

/// A running progressive session: a typed event stream plus hot-swapping
/// model handles. See the [module docs](crate::client::session) for the
/// event protocol.
pub struct ProgressiveSession {
    events: BoundedQueue<SessionEvent>,
    approx: HashMap<String, ApproxModel>,
    driver: Option<JoinHandle<Result<SessionReport>>>,
}

impl ProgressiveSession {
    /// Builder for a single-model session.
    pub fn builder(model: &str) -> SessionBuilder {
        let mut b = SessionBuilder::new(false);
        b.specs.push(ModelSpec {
            request: FetchRequest::new(model),
            priority: 1.0,
        });
        b
    }

    /// Builder for a multiplexed session: several models interleaved by
    /// weighted-fair priority over a single keep-alive connection. Add
    /// models with [`SessionBuilder::add_model`].
    ///
    /// Multiplexed limitations (single-model sessions support all of
    /// these): [`SessionBuilder::mode`] is ignored — delivery is one
    /// request at a time on one connection; a dropped connection fails
    /// fast instead of resuming ([`SessionBuilder::resume_retries`] does
    /// not apply); [`SessionBuilder::policy`] only controls whether
    /// intermediate stages are published (`FinalOnly` publishes just the
    /// last stage of each runtime-bound model).
    pub fn multiplex() -> SessionBuilder {
        SessionBuilder::new(true)
    }

    /// Blocking: the next event, or `None` once the stream closed. After
    /// `None`, call [`ProgressiveSession::finish`] for the report.
    pub fn next_event(&self) -> Option<SessionEvent> {
        self.events.pop()
    }

    /// Non-blocking poll: `None` when no event is currently queued (the
    /// session may still be running).
    pub fn try_event(&self) -> Option<SessionEvent> {
        self.events.try_pop()
    }

    /// Blocking iterator over the remaining events.
    pub fn events(&self) -> Events<'_> {
        Events(self)
    }

    /// The hot-swappable handle of `model` (present when a runtime was
    /// bound). Clone it to share with a coordinator.
    pub fn approx(&self, model: &str) -> Option<&ApproxModel> {
        self.approx.get(model)
    }

    /// Single-model convenience accessor for [`ProgressiveSession::approx`].
    pub fn approx_model(&self) -> Option<&ApproxModel> {
        if self.approx.len() == 1 {
            self.approx.values().next()
        } else {
            None
        }
    }

    /// Drain any unread events, wait for the driver, and return the
    /// final report (or the driver's error).
    pub fn finish(mut self) -> Result<SessionReport> {
        while self.events.pop().is_some() {}
        let driver = self.driver.take().expect("driver joined once");
        match driver.join() {
            Ok(report) => report,
            Err(_) => anyhow::bail!("session driver panicked"),
        }
    }

    /// Drive the session to completion, discarding events. Equivalent to
    /// [`ProgressiveSession::finish`] right after `start()`.
    pub fn run(self) -> Result<SessionReport> {
        self.finish()
    }
}

impl Drop for ProgressiveSession {
    fn drop(&mut self) {
        // A consumer bailing early closes the stream; the driver notices
        // at its next event and unwinds instead of blocking forever.
        self.events.close();
    }
}

/// Blocking event iterator returned by [`ProgressiveSession::events`].
pub struct Events<'a>(&'a ProgressiveSession);

impl Iterator for Events<'_> {
    type Item = SessionEvent;

    fn next(&mut self) -> Option<SessionEvent> {
        self.0.next_event()
    }
}

// ---------------------------------------------------------------- driver

struct DriverConfig {
    addr: SocketAddr,
    specs: Vec<ModelSpec>,
    mode: ExecMode,
    policy: InferencePolicy,
    retry: RetryPolicy,
    cache_dir: Option<PathBuf>,
    workload: Option<Workload>,
    multiplex: bool,
    layer_gate: Option<Arc<LayerGate>>,
}

fn emit(q: &BoundedQueue<SessionEvent>, ev: SessionEvent) -> Result<()> {
    anyhow::ensure!(q.push(ev), "session event stream closed by the consumer");
    Ok(())
}

/// Assembler for a freshly parsed manifest. When the session will
/// publish per-stage reconstructions (a runtime is bound and the policy
/// isn't final-only), Eq. 5 is folded into fragment absorption so the
/// stage-boundary reconstruct inside [`publish_stage`] is bookkeeping,
/// not a full dequant pass. `FinalOnly` reconstructs exactly once, so
/// eager per-stage dequant would be pure wasted work there.
fn new_assembler(
    m: PnetManifest,
    publishes: bool,
    policy: InferencePolicy,
    gated: bool,
) -> Assembler {
    let mut asm = Assembler::new(m);
    // a streaming gate consumes per-layer reconstructions mid-stage, so
    // it needs eager dequant regardless of the publish policy
    asm.set_eager_dequant(gated || (publishes && policy != InferencePolicy::FinalOnly));
    asm
}

/// Emit one `LayerReady` — publishing the layer's dequantized segment
/// into the streaming gate first, so by the time a consumer observes the
/// event the weights are already waitable.
fn emit_layer_ready(
    q: &BoundedQueue<SessionEvent>,
    gate: Option<&LayerGate>,
    asm: &Assembler,
    model: &str,
    layer: usize,
    stage: usize,
    t: f64,
) -> Result<()> {
    if let Some(g) = gate {
        let range = asm.layer_weight_range(layer);
        g.publish_layer(layer, stage, t, range.clone(), &asm.flat()[range]);
    }
    emit(
        q,
        SessionEvent::LayerReady {
            model: model.to_string(),
            layer,
            stage,
            cum_bits: asm.manifest().schedule.cum_bits(stage),
            t,
        },
    )
}

/// Drain and emit every per-layer completion recorded since the last
/// drain. Call after each absorbed fragment, *before* any stage-level
/// event, so `LayerReady { stage: s }` always precedes
/// `StageComplete { stage: s }`.
fn drain_layers(
    q: &BoundedQueue<SessionEvent>,
    gate: Option<&LayerGate>,
    asm: &mut Assembler,
    model: &str,
    t: f64,
) -> Result<()> {
    for (layer, stage) in asm.drain_layer_events() {
        emit_layer_ready(q, gate, asm, model, layer, stage, t)?;
    }
    Ok(())
}

fn should_infer(policy: InferencePolicy, done_stage: usize, asm: &Assembler) -> bool {
    match policy {
        InferencePolicy::EveryStage => true,
        InferencePolicy::LatestOnly => true,
        InferencePolicy::FinalOnly => done_stage + 1 == asm.manifest().schedule.stages(),
    }
}

/// Version-skew guard + reconstruct + publish + `ModelReady` emit,
/// shared by the single-model and multiplexed paths. Timestamps the
/// event at reconstruct-done time on `start`'s clock; returns
/// `(cum_bits, t_reconstruct_done)`.
fn publish_stage(
    q: &BoundedQueue<SessionEvent>,
    approx: &ApproxModel,
    model: &str,
    asm: &mut Assembler,
    start: Instant,
) -> Result<(u32, f64)> {
    // registry/server version skew surfaces as an error, not a panic
    // inside ApproxModel::publish
    anyhow::ensure!(
        asm.manifest().param_count() == approx.manifest().param_count,
        "server container for '{model}' carries {} params but the bound \
         runtime expects {}",
        asm.manifest().param_count(),
        approx.manifest().param_count
    );
    let stage = asm.stages_complete() - 1;
    let cum_bits = asm.cum_bits();
    asm.reconstruct()?;
    let t1 = start.elapsed().as_secs_f64();
    let version = approx.publish(asm.flat(), cum_bits);
    emit(
        q,
        SessionEvent::ModelReady {
            model: model.to_string(),
            stage,
            cum_bits,
            version,
            t: t1,
        },
    )?;
    Ok((cum_bits, t1))
}

fn drive(
    cfg: DriverConfig,
    q: &BoundedQueue<SessionEvent>,
    approx: &HashMap<String, ApproxModel>,
) -> Result<SessionReport> {
    if cfg.multiplex {
        drive_multiplex(cfg, q, approx)
    } else {
        drive_single(cfg, q, approx)
    }
}

/// Per-stage bookkeeping shared by the serial/concurrent/cache paths of
/// a single-model session.
struct StageCtx<'a> {
    model: String,
    policy: InferencePolicy,
    workload: Option<&'a Workload>,
    approx: Option<&'a ApproxModel>,
    gate: Option<&'a LayerGate>,
    q: &'a BoundedQueue<SessionEvent>,
    start: Instant,
    timeline: Timeline,
    results: Vec<StageResult>,
    order: Vec<(String, usize)>,
    resumed: usize,
    reconnects: usize,
    /// the session's `client.request` span context, if tracing is active
    trace: Option<TraceCtx>,
    /// span covering the currently transferring stage
    cur_stage: Option<obs::SpanGuard>,
}

impl StageCtx<'_> {
    fn emit(&self, ev: SessionEvent) -> Result<()> {
        emit(self.q, ev)
    }

    /// Build the model's assembler for a freshly parsed manifest and,
    /// when a streaming gate is attached, validate the container's layer
    /// annotation against it — a missing annotation would silently never
    /// publish and leave the executor blocked until close.
    fn make_assembler(&mut self, m: PnetManifest) -> Result<Assembler> {
        let asm = new_assembler(m, self.approx.is_some(), self.policy, self.gate.is_some());
        if let Some(g) = self.gate {
            anyhow::ensure!(
                asm.layer_count() > 0,
                "streaming gate for '{}' requires a layer-annotated (LayerMajor) container",
                self.model
            );
            anyhow::ensure!(
                g.layers() == asm.layer_count(),
                "streaming gate for '{}' is sized for {} layers, container has {}",
                self.model,
                g.layers(),
                asm.layer_count()
            );
        }
        // the manifest opens stage 0's transfer window
        if self.cur_stage.is_none() {
            self.cur_stage = self.trace.map(|ctx| obs::begin_child("client.stage", ctx));
        }
        Ok(asm)
    }

    /// Drain per-layer completions (→ `LayerReady`, gate publications).
    fn emit_layers(&self, asm: &mut Assembler, t: f64) -> Result<()> {
        drain_layers(self.q, self.gate, asm, &self.model, t)
    }

    fn emit_resumed(
        &mut self,
        stage: usize,
        source: ResumeSource,
        backoff: Duration,
    ) -> Result<()> {
        self.resumed += 1;
        if source == ResumeSource::Reconnect {
            self.reconnects += 1;
        }
        let attempt = self.resumed;
        self.emit(SessionEvent::Resumed {
            model: self.model.clone(),
            stage,
            attempt,
            source,
            backoff,
        })
    }

    /// Timeline + `StageComplete` bookkeeping for a freshly completed
    /// stage (no reconstruction yet).
    fn note_stage(&mut self, asm: &Assembler, done: usize, t: f64) -> Result<()> {
        if let Some(mut sp) = self.cur_stage.take() {
            sp.attr("stage", done);
            sp.end();
        }
        self.timeline.push(t, done, EventKind::StageTransferDone);
        if done + 1 < asm.manifest().schedule.stages() {
            self.timeline.push(t, done + 1, EventKind::StageTransferStart);
            self.cur_stage = self.trace.map(|ctx| obs::begin_child("client.stage", ctx));
        }
        self.order.push((self.model.clone(), done));
        self.emit(SessionEvent::StageComplete {
            model: self.model.clone(),
            stage: done,
            cum_bits: asm.manifest().schedule.cum_bits(done),
            t,
        })
    }

    /// Reconstruct the newest complete stage, publish it into the
    /// session's `ApproxModel` (→ `ModelReady`), and run the workload if
    /// one is configured (→ `Inference`). No-op without a bound runtime.
    fn reconstruct_and_publish(&mut self, asm: &mut Assembler, t_transfer_done: f64) -> Result<()> {
        let Some(approx) = self.approx else {
            return Ok(());
        };
        let stage = asm.stages_complete() - 1;
        let t0 = self.start.elapsed().as_secs_f64();
        self.timeline.push(t0, stage, EventKind::ReconstructStart);
        let recon_span = self.trace.map(|ctx| {
            let mut sp = obs::begin_child("client.reconstruct", ctx);
            sp.attr("stage", stage);
            sp
        });
        let (cum_bits, t1) = publish_stage(self.q, approx, &self.model, asm, self.start)?;
        drop(recon_span);
        self.timeline.push(t1, stage, EventKind::ReconstructDone);
        if let Some(w) = self.workload {
            self.timeline.push(t1, stage, EventKind::InferStart);
            let infer_span = self.trace.map(|ctx| {
                let mut sp = obs::begin_child("client.infer", ctx);
                sp.attr("stage", stage);
                sp
            });
            let out = approx.infer(&w.images, w.n)?;
            drop(infer_span);
            let t2 = self.start.elapsed().as_secs_f64();
            self.timeline.push(t2, stage, EventKind::InferDone);
            self.timeline.push(t2, stage, EventKind::OutputReady);
            let result = StageResult {
                stage,
                cum_bits,
                output: out.output,
                t_transfer_done,
                t_output_ready: t2,
            };
            self.emit(SessionEvent::Inference {
                model: self.model.clone(),
                result: result.clone(),
            })?;
            self.results.push(result);
        }
        Ok(())
    }

    /// Emit `Finished` and assemble the report. `connects` is the number
    /// of initial wire connections (0 for a pure cache replay); reconnect
    /// resumes are added on top.
    fn finish_report(
        self,
        model: &str,
        asm: Option<Assembler>,
        t_transfer_complete: f64,
        bytes: u64,
        cache_hit: bool,
        connects: usize,
    ) -> Result<SessionReport> {
        let t_total = self
            .results
            .last()
            .map(|r| r.t_output_ready)
            .unwrap_or(t_transfer_complete)
            .max(t_transfer_complete);
        let summary = SessionSummary {
            t_transfer_complete,
            t_total,
            bytes,
            resumed: self.resumed,
            cache_hit,
        };
        self.emit(SessionEvent::Finished(summary.clone()))?;
        let mut assemblers = HashMap::new();
        if let Some(a) = asm {
            assemblers.insert(model.to_string(), a);
        }
        Ok(SessionReport {
            results: self.results,
            assemblers,
            timeline: self.timeline,
            summary,
            requests: connects + self.reconnects,
            order: self.order,
        })
    }
}

/// Items forwarded from the download loop to the stage handler.
enum WireItem {
    Event(TimedEvent),
    Resumed { stage: usize, backoff: Duration },
}

/// Read the socket until the window completes, transparently resuming at
/// the last complete stage boundary while retries remain, and persisting
/// the captured canonical prefix at every new stage boundary when a
/// cache is attached. Returns (last event time, body bytes received,
/// including any warm-start seed counted into the downloader).
///
/// Persistence rewrites the whole prefix per boundary (atomic tmp +
/// rename — crash-safe, never a torn partial on disk) and the capture
/// buffer holds the container alongside the assembler's code buffers:
/// caching trades ~stage-count× write amplification and one extra
/// container copy in RAM for byte-exact resumability. Containers are
/// model-download sized (MBs), so both are deliberate.
fn pump<F>(
    dl: &mut Downloader,
    mut retry: Retry,
    persist: Option<(&ModelCache, &FetchRequest)>,
    mut sink: F,
) -> Result<(f64, u64)>
where
    F: FnMut(WireItem) -> Result<()>,
{
    let mut t_last = 0.0;
    let mut persisted = dl.stage_boundary();
    while !dl.is_done() {
        let events = loop {
            match dl.next_events() {
                Ok(evs) => break evs,
                Err(e) => {
                    // a failed reconnect (e.g. the outage is ongoing) also
                    // spends a retry rather than aborting while budget
                    // remains; each dial waits out the policy's jittered
                    // backoff first
                    let mut last = e;
                    loop {
                        if !dl.can_resume() {
                            return Err(last);
                        }
                        let Some(backoff) = retry.backoff() else {
                            return Err(last);
                        };
                        let boundary = dl.stage_boundary();
                        crate::log_warn!(
                            "download interrupted ({last:#}); resuming at stage {boundary} \
                             after {backoff:?}"
                        );
                        match dl.resume_at_stage(boundary) {
                            Ok(()) => {
                                sink(WireItem::Resumed {
                                    stage: boundary,
                                    backoff,
                                })?;
                                break;
                            }
                            Err(re) => last = re,
                        }
                    }
                }
            }
        };
        for te in events {
            t_last = te.t;
            sink(WireItem::Event(te))?;
        }
        if let Some((cache, req)) = persist {
            let boundary = dl.stage_boundary();
            if boundary > persisted {
                if let Some(cap) = dl.captured() {
                    if let Err(e) = cache.store_partial(req, cap) {
                        crate::log_warn!("cache persist failed: {e:#}");
                    }
                }
                persisted = boundary;
            }
        }
    }
    Ok((t_last, dl.bytes_received()))
}

/// Replay a complete cached container: the full event stream without the
/// network.
fn replay_container(
    mut ctx: StageCtx<'_>,
    model: &str,
    bytes: &[u8],
) -> Result<SessionReport> {
    ctx.timeline.push(0.0, 0, EventKind::StageTransferStart);
    let mut parser = FrameParser::new();
    let mut asm: Option<Assembler> = None;
    for ev in parser.feed(bytes)? {
        match ev {
            ParserEvent::Manifest(m) => asm = Some(ctx.make_assembler(*m)?),
            ParserEvent::Fragment {
                stage,
                tensor,
                payload,
            } => {
                let a = asm.as_mut().context("manifest precedes fragments")?;
                let done = a.absorb(stage, tensor, &payload)?;
                let t = ctx.start.elapsed().as_secs_f64();
                ctx.emit_layers(a, t)?;
                if let Some(done) = done {
                    ctx.note_stage(a, done, t)?;
                    if should_infer(ctx.policy, done, a) {
                        ctx.reconstruct_and_publish(a, t)?;
                    }
                }
            }
        }
    }
    anyhow::ensure!(parser.is_done(), "cached container incomplete");
    let asm = asm.context("cached container had no manifest")?;
    ctx.finish_report(model, Some(asm), 0.0, 0, true, 0)
}

/// Try to warm-start from a persisted partial: absorb it silently, and
/// only if the server accepts a stage-boundary resume emit the cached
/// stages (each exactly once) followed by a `Resumed(Cache)` marker.
/// Returns `None` for a cold start.
/// On success returns the pre-seeded assembler, the resumed downloader,
/// and the cached prefix length in bytes (already counted into the
/// downloader's progress accounting, but *not* network traffic).
fn warm_start(
    ctx: &mut StageCtx<'_>,
    cache: &ModelCache,
    addr: &SocketAddr,
    req: &FetchRequest,
) -> Result<Option<(Assembler, Downloader, u64)>> {
    let Some(part) = cache.load_partial(req) else {
        return Ok(None);
    };
    let mut parser = FrameParser::new();
    let Ok(events) = parser.feed(&part) else {
        crate::log_warn!("cached partial for '{}' unreadable; refetching", req.model);
        return Ok(None);
    };
    let mut asm: Option<Assembler> = None;
    for ev in events {
        match ev {
            ParserEvent::Manifest(m) => asm = Some(ctx.make_assembler(*m)?),
            ParserEvent::Fragment {
                stage,
                tensor,
                payload,
            } => {
                let Some(a) = asm.as_mut() else {
                    return Ok(None);
                };
                if a.absorb(stage, tensor, &payload).is_err() {
                    return Ok(None);
                }
            }
        }
    }
    let Some(mut asm) = asm else {
        return Ok(None);
    };
    let boundary = asm.stages_complete();
    if boundary == 0 || boundary >= asm.manifest().schedule.stages() {
        // nothing usable (complete partials were promoted earlier)
        return Ok(None);
    }
    let manifest = asm.manifest().clone();
    let prefix_len = manifest
        .stage_index()
        .body_range(Some((0, boundary as u32)))?
        .end;
    anyhow::ensure!(
        prefix_len <= part.len(),
        "partial shorter than its parsed stages"
    );
    let mut dl = match Downloader::connect_resumed(addr, req, manifest, boundary, prefix_len as u64)
    {
        Ok(dl) => dl,
        Err(e) => {
            // stale partial (server re-encoded?) or refused range: restart
            crate::log_warn!("cache resume failed ({e:#}); refetching '{}'", req.model);
            return Ok(None);
        }
    };
    dl.enable_capture(part[..prefix_len].to_vec());
    // all timestamps — cached replays, network stages, reconstruct and
    // inference — share the downloader's clock, so the timeline stays
    // monotonic and excludes the pre-connect cache parsing
    ctx.start = dl.start_instant();
    // replay the cached stages as events — each stage exactly once, its
    // layer completions (recorded during the silent absorb above) ahead
    // of it, exactly as a live transfer would have interleaved them …
    let cached_layers = asm.drain_layer_events();
    for s in 0..boundary {
        let t = ctx.start.elapsed().as_secs_f64();
        for &(layer, stage) in cached_layers.iter().filter(|&&(_, st)| st == s) {
            emit_layer_ready(ctx.q, ctx.gate, &asm, &ctx.model, layer, stage, t)?;
        }
        ctx.note_stage(&asm, s, t)?;
    }
    // … reconstructing once at the boundary (skip-to-newest semantics)
    let t = ctx.start.elapsed().as_secs_f64();
    if should_infer(ctx.policy, boundary - 1, &asm) {
        ctx.reconstruct_and_publish(&mut asm, t)?;
    }
    // layers already completed inside the partially cached stage
    // `boundary` announce now — the wire re-delivers those fragments, but
    // duplicates never re-emit, so each (layer, stage) fires exactly once
    let t = ctx.start.elapsed().as_secs_f64();
    for &(layer, stage) in cached_layers.iter().filter(|&&(_, st)| st >= boundary) {
        emit_layer_ready(ctx.q, ctx.gate, &asm, &ctx.model, layer, stage, t)?;
    }
    ctx.emit_resumed(boundary, ResumeSource::Cache, Duration::ZERO)?;
    Ok(Some((asm, dl, prefix_len as u64)))
}

fn drive_single(
    cfg: DriverConfig,
    q: &BoundedQueue<SessionEvent>,
    approx_map: &HashMap<String, ApproxModel>,
) -> Result<SessionReport> {
    let DriverConfig {
        addr,
        specs,
        mode,
        policy,
        retry,
        cache_dir,
        workload,
        multiplex: _,
        layer_gate,
    } = cfg;
    let mut req = specs.into_iter().next().expect("one spec").request;
    let model = req.model.clone();
    // Root span for the whole request. With tracing disabled (the
    // default) the guard is disarmed and the wire frame stays
    // byte-identical to an untraced v1 request.
    let mut root_span = obs::begin("client.request");
    root_span.attr("model", &model);
    let trace = root_span.armed().then(|| root_span.ctx());
    if let Some(tc) = trace {
        req = req.with_trace(tc);
        if let Some(g) = &layer_gate {
            g.set_trace(tc);
        }
    }
    let mut ctx = StageCtx {
        model: model.clone(),
        policy,
        workload: workload.as_ref(),
        approx: approx_map.get(&model),
        gate: layer_gate.as_deref(),
        q,
        start: clock::now(),
        timeline: Timeline::new(),
        results: Vec::new(),
        order: Vec::new(),
        resumed: 0,
        reconnects: 0,
        trace,
        cur_stage: None,
    };

    let cache = match &cache_dir {
        Some(dir) => Some(ModelCache::open(dir)?),
        None => None,
    };
    if let Some(c) = &cache {
        // a finished download that crashed before promotion
        if let Some(part) = c.load_partial(&req) {
            if PnetReader::from_bytes(&part).is_ok() {
                let _ = c.store_complete(&req, &part);
            }
        }
        if let Some(bytes) = c.load_complete(&req) {
            return replay_container(ctx, &model, &bytes);
        }
    }

    ctx.timeline.push(0.0, 0, EventKind::StageTransferStart);
    let mut asm_opt: Option<Assembler> = None;
    // bytes served from the cached prefix — included in the downloader's
    // progress accounting but subtracted from the network-bytes summary
    let mut seeded = 0u64;
    let mut dl = match &cache {
        Some(c) => match warm_start(&mut ctx, c, &addr, &req)? {
            Some((asm, dl, prefix)) => {
                asm_opt = Some(asm);
                seeded = prefix;
                dl
            }
            None => {
                let mut dl = Downloader::connect(&addr, &req)?;
                dl.enable_capture(Vec::new());
                dl
            }
        },
        None => Downloader::connect(&addr, &req)?,
    };
    // event times (TimedEvent.t) are relative to the downloader's start;
    // align the reconstruct/infer clock to the same base (idempotent
    // after a warm start, which already aligned it before emitting)
    ctx.start = dl.start_instant();
    let persist: Option<(&ModelCache, &FetchRequest)> = cache.as_ref().map(|c| (c, &req));
    // one backoff sequence per download, salted by the model name so the
    // jitter schedule is deterministic per model (and decorrelated across
    // a fleet of sessions fetching different models)
    let retry = retry.start(Clock::real(), crate::fleet::placement::fnv1a(model.as_bytes()));

    let (t_transfer_complete, bytes, captured) = match mode {
        ExecMode::Serial => {
            let _ = dl.set_small_recv_buffer();
            let (t_last, bytes) = pump(&mut dl, retry, persist, |item| match item {
                WireItem::Resumed { stage, backoff } => {
                    ctx.emit_resumed(stage, ResumeSource::Reconnect, backoff)
                }
                WireItem::Event(TimedEvent { t, event }) => match event {
                    ParserEvent::Manifest(m) => {
                        asm_opt = Some(ctx.make_assembler(*m)?);
                        Ok(())
                    }
                    ParserEvent::Fragment {
                        stage,
                        tensor,
                        payload,
                    } => {
                        let asm = asm_opt.as_mut().expect("manifest precedes fragments");
                        let done = asm.absorb(stage, tensor, &payload)?;
                        ctx.emit_layers(asm, t)?;
                        if let Some(done) = done {
                            ctx.note_stage(asm, done, t)?;
                            if should_infer(ctx.policy, done, asm) {
                                // Serial: block the download thread.
                                ctx.reconstruct_and_publish(asm, t)?;
                            }
                        }
                        Ok(())
                    }
                },
            })?;
            (t_last, bytes, dl.take_captured())
        }
        ExecMode::Concurrent => {
            let wire: BoundedQueue<WireItem> = BoundedQueue::new(1024);
            std::thread::scope(|scope| -> Result<(f64, u64, Option<Vec<u8>>)> {
                // ---- download thread: read + parse + forward only
                let wp = wire.clone();
                let downloader =
                    scope.spawn(move || -> (Result<(f64, u64)>, Option<Vec<u8>>) {
                        let res = pump(&mut dl, retry, persist, |item| {
                            anyhow::ensure!(wp.push(item), "event queue closed early");
                            Ok(())
                        });
                        // Always close the queue — also on error — or the
                        // worker would block forever on pop().
                        wp.close();
                        (res, dl.take_captured())
                    });

                // ---- worker (this thread): assemble + reconstruct + infer
                let mut pending: Option<f64> = None;
                let worker: Result<()> = (|| {
                    loop {
                        // Drain everything available; keep only the newest
                        // completed stage if the policy allows skipping.
                        let next = if pending.is_some() {
                            wire.try_pop()
                        } else {
                            wire.pop()
                        };
                        match next {
                            Some(WireItem::Resumed { stage, backoff }) => {
                                ctx.emit_resumed(stage, ResumeSource::Reconnect, backoff)?;
                            }
                            Some(WireItem::Event(TimedEvent { t, event })) => match event {
                                ParserEvent::Manifest(m) => {
                                    asm_opt = Some(ctx.make_assembler(*m)?);
                                }
                                ParserEvent::Fragment {
                                    stage,
                                    tensor,
                                    payload,
                                } => {
                                    let asm =
                                        asm_opt.as_mut().expect("manifest precedes fragments");
                                    let done = asm.absorb(stage, tensor, &payload)?;
                                    ctx.emit_layers(asm, t)?;
                                    if let Some(done) = done {
                                        ctx.note_stage(asm, done, t)?;
                                        if ctx.policy == InferencePolicy::LatestOnly {
                                            pending = Some(t); // overwrite older
                                        } else if should_infer(ctx.policy, done, asm) {
                                            ctx.reconstruct_and_publish(asm, t)?;
                                        }
                                    }
                                }
                            },
                            None => {
                                // Queue idle (or closed): run a pending
                                // (possibly skipped-to) stage, else finish.
                                if let Some(t) = pending.take() {
                                    let asm =
                                        asm_opt.as_mut().expect("manifest precedes fragments");
                                    ctx.reconstruct_and_publish(asm, t)?;
                                    continue;
                                }
                                // pending was None, so this None came from
                                // a blocking pop() on a closed queue.
                                break;
                            }
                        }
                    }
                    Ok(())
                })();
                // If the worker errors, close the queue so the download
                // thread cannot block pushing into a full queue.
                if worker.is_err() {
                    wire.close();
                }
                let (dl_res, captured) = downloader.join().expect("session download thread");
                worker?; // a worker error is the root cause — report it
                let (t_last, bytes) = dl_res?;
                Ok((t_last, bytes, captured))
            })?
        }
    };

    if let (Some(c), Some(cap)) = (&cache, &captured) {
        if let Err(e) = c.store_complete(&req, cap) {
            crate::log_warn!("cache promote failed: {e:#}");
        }
    }
    root_span.attr("bytes", bytes.saturating_sub(seeded));
    // `bytes` from the downloader counts the cached prefix; the summary
    // reports genuine network traffic only
    ctx.finish_report(
        &model,
        asm_opt,
        t_transfer_complete,
        bytes.saturating_sub(seeded),
        false,
        1,
    )
}

/// Read exactly `remaining` body bytes (never more — the next response's
/// status frame follows on the same stream) and feed them to the parser.
fn read_stage_body(
    stream: &mut TcpStream,
    remaining: u64,
    parser: &mut FrameParser,
) -> Result<Vec<ParserEvent>> {
    use std::io::Read;
    let mut events = Vec::new();
    let mut left = remaining as usize;
    let mut buf = [0u8; 8192];
    while left > 0 {
        let want = left.min(buf.len());
        let n = stream.read(&mut buf[..want])?;
        anyhow::ensure!(n > 0, "connection closed with {left} body bytes left");
        events.extend(parser.feed(&buf[..n])?);
        left -= n;
    }
    Ok(events)
}

/// Pipelined multi-model delivery: ONE connection, many stage-range
/// requests, interleaved across models by the coordinator's weighted-fair
/// plan. Phase 1 fetches stage 0 of every model (yielding each manifest,
/// hence each stage's exact wire size); phase 2 requests the remaining
/// stages one at a time in plan order, keeping the connection alive.
fn drive_multiplex(
    cfg: DriverConfig,
    q: &BoundedQueue<SessionEvent>,
    approx_map: &HashMap<String, ApproxModel>,
) -> Result<SessionReport> {
    let addr = cfg.addr;
    let specs = cfg.specs;
    let start = clock::now();
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("{} {addr}", crate::server::service::CONNECT_CONTEXT))?;
    stream.set_nodelay(true)?;

    let mut assemblers: HashMap<String, Assembler> = HashMap::new();
    let mut parsers: HashMap<String, FrameParser> = HashMap::new();
    let mut bytes = 0u64;
    let mut requests = 0usize;
    let mut order: Vec<(String, usize)> = Vec::new();

    // completion handler shared by both phases; publishes every stage of
    // a runtime-bound model (FinalOnly defers to the last stage — the
    // inference policies beyond that have no workload to govern here)
    let policy = cfg.policy;
    let stage_done = |assemblers: &mut HashMap<String, Assembler>,
                          model: &str,
                          done: usize,
                          t: f64|
     -> Result<()> {
        let asm = assemblers.get_mut(model).expect("assembler exists");
        emit(
            q,
            SessionEvent::StageComplete {
                model: model.to_string(),
                stage: done,
                cum_bits: asm.manifest().schedule.cum_bits(done),
                t,
            },
        )?;
        if let Some(approx) = approx_map.get(model) {
            if should_infer(policy, done, asm) {
                publish_stage(q, approx, model, asm, start)?;
            }
        }
        Ok(())
    };

    // Phase 1: stage 0 of every model — the manifest arrives with it,
    // so stage sizes become known and the rest can be planned.
    for spec in &specs {
        let req = spec
            .request
            .clone()
            .with_stages(0, 1)
            .with_keep_alive(true);
        let resp = request_on(&mut stream, &req)?;
        let mut parser = FrameParser::for_stage_prefix(1);
        let events = read_stage_body(&mut stream, resp.remaining, &mut parser)?;
        anyhow::ensure!(parser.is_done(), "stage 0 of {} incomplete", req.model);
        bytes += resp.remaining;
        requests += 1;
        order.push((req.model.clone(), 0));
        let mut completed: Option<usize> = None;
        for ev in events {
            match ev {
                ParserEvent::Manifest(man) => {
                    let publishes = approx_map.contains_key(&req.model);
                    assemblers.insert(
                        req.model.clone(),
                        new_assembler(*man, publishes, policy, false),
                    );
                }
                ParserEvent::Fragment {
                    stage,
                    tensor,
                    payload,
                } => {
                    let asm = assemblers
                        .get_mut(&req.model)
                        .context("manifest precedes fragments")?;
                    if let Some(done) = asm.absorb(stage, tensor, &payload)? {
                        completed = Some(done);
                    }
                    drain_layers(q, None, asm, &req.model, start.elapsed().as_secs_f64())?;
                }
            }
        }
        if let Some(done) = completed {
            stage_done(
                &mut assemblers,
                &req.model,
                done,
                start.elapsed().as_secs_f64(),
            )?;
        }
        // the parser keeps the manifest; later windows reuse it
        parsers.insert(req.model.clone(), parser);
    }

    // Phase 2: weighted-fair plan over the remaining stages.
    let metas: Vec<InterleaveModel> = specs
        .iter()
        .map(|spec| {
            let man = parsers[&spec.request.model]
                .manifest()
                .context("phase 1 always parses the manifest")?;
            let idx = man.stage_index();
            let stage_bytes: Vec<u64> = (1..man.schedule.stages())
                .map(|s| idx.stage_span(s, s + 1).map(|r| r.len() as u64))
                .collect::<Result<_>>()?;
            Ok(InterleaveModel {
                name: spec.request.model.clone(),
                first_stage: 1,
                stage_bytes,
                priority: spec.priority,
            })
        })
        .collect::<Result<_>>()?;
    let plan = interleave_stages(&metas);

    for (i, entry) in plan.iter().enumerate() {
        let spec = specs
            .iter()
            .find(|s| s.request.model == entry.model)
            .expect("plan only contains requested models");
        let keep = i + 1 < plan.len();
        let req = spec
            .request
            .clone()
            .with_stages(entry.stage as u32, entry.stage as u32 + 1)
            .with_keep_alive(keep);
        let resp = request_on(&mut stream, &req)?;
        let parser = parsers
            .get_mut(&entry.model)
            .expect("parser created in phase 1");
        parser.rewindow(entry.stage, entry.stage + 1)?;
        let events = read_stage_body(&mut stream, resp.remaining, parser)?;
        anyhow::ensure!(
            parser.is_done(),
            "stage {} of {} incomplete",
            entry.stage,
            entry.model
        );
        bytes += resp.remaining;
        requests += 1;
        order.push((entry.model.clone(), entry.stage));
        let mut completed: Option<usize> = None;
        for ev in events {
            if let ParserEvent::Fragment {
                stage,
                tensor,
                payload,
            } = ev
            {
                let asm = assemblers
                    .get_mut(&entry.model)
                    .expect("assembler created in phase 1");
                if let Some(done) = asm.absorb(stage, tensor, &payload)? {
                    completed = Some(done);
                }
                drain_layers(q, None, asm, &entry.model, start.elapsed().as_secs_f64())?;
            }
        }
        if let Some(done) = completed {
            stage_done(
                &mut assemblers,
                &entry.model,
                done,
                start.elapsed().as_secs_f64(),
            )?;
        }
    }

    let t = start.elapsed().as_secs_f64();
    let summary = SessionSummary {
        t_transfer_complete: t,
        t_total: t,
        bytes,
        resumed: 0,
        cache_hit: false,
    };
    emit(q, SessionEvent::Finished(summary.clone()))?;
    Ok(SessionReport {
        results: Vec::new(),
        assemblers,
        timeline: Timeline::new(),
        summary,
        requests,
        order,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fixture::synthetic_server;

    #[test]
    fn builder_rejects_inconsistent_configs() {
        // no address
        assert!(ProgressiveSession::builder("alpha").start().is_err());
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        // workload without a bound runtime
        assert!(ProgressiveSession::builder("alpha")
            .addr(addr)
            .workload(vec![0.0; 4], 1)
            .start()
            .is_err());
        // duplicate models
        assert!(ProgressiveSession::multiplex()
            .addr(addr)
            .add_model(FetchRequest::new("alpha"), 1.0)
            .add_model(FetchRequest::new("alpha"), 1.0)
            .start()
            .is_err());
        // multiplexed cache
        assert!(ProgressiveSession::multiplex()
            .addr(addr)
            .add_model(FetchRequest::new("alpha"), 1.0)
            .add_model(FetchRequest::new("beta"), 1.0)
            .cache_dir(std::env::temp_dir().join("prognet-nope"))
            .start()
            .is_err());
        // no models at all
        assert!(ProgressiveSession::multiplex().addr(addr).start().is_err());
    }

    #[test]
    fn download_only_session_emits_stages_and_finishes() {
        let (server, repo) = synthetic_server("sess-dlonly").unwrap();
        let handle = ProgressiveSession::builder("alpha")
            .addr(server.addr())
            .start()
            .unwrap();
        let mut stages = Vec::new();
        let mut layers = Vec::new();
        let mut finished = 0;
        for ev in handle.events() {
            match ev {
                SessionEvent::StageComplete { stage, .. } => stages.push(stage),
                SessionEvent::LayerReady { layer, stage, .. } => {
                    // every LayerReady of a stage precedes its StageComplete
                    assert!(!stages.contains(&stage), "layer {layer} late for {stage}");
                    layers.push((layer, stage));
                }
                SessionEvent::ModelReady { .. } | SessionEvent::Inference { .. } => {
                    panic!("no runtime bound — no model/inference events")
                }
                SessionEvent::Finished(s) => {
                    finished += 1;
                    assert!(!s.cache_hit);
                    assert_eq!(s.resumed, 0);
                }
                SessionEvent::Resumed { .. } => panic!("no resume expected"),
            }
        }
        assert_eq!(stages, (0..8).collect::<Vec<_>>());
        // "alpha" is (w1+b1)(w2) = 2 layers; stage-major delivery
        // completes them in order within every stage
        let want: Vec<(usize, usize)> = (0..8).flat_map(|s| [(0, s), (1, s)]).collect();
        assert_eq!(layers, want);
        assert_eq!(finished, 1);
        let report = handle.finish().unwrap();
        let asm = report.assembler("alpha").unwrap();
        assert!(asm.is_complete());
        // assembled codes match a direct decode of the cached container
        let container = repo
            .container("alpha", &Schedule::paper_default())
            .unwrap();
        let r = PnetReader::from_bytes(&container).unwrap();
        let mut direct = Assembler::new(r.manifest.clone());
        for s in 0..r.manifest.schedule.stages() {
            for t in 0..r.manifest.tensors.len() {
                direct.absorb(s, t, &r.fragments[s][t]).unwrap();
            }
        }
        assert_eq!(asm.codes_flat(), direct.codes_flat());
        assert_eq!(report.summary.bytes, container.len() as u64);
    }

    #[test]
    fn dropping_the_handle_cancels_the_driver() {
        let (server, _repo) = synthetic_server("sess-drop").unwrap();
        let handle = ProgressiveSession::builder("alpha")
            .addr(server.addr())
            .start()
            .unwrap();
        // read one event, then walk away — must not hang or leak a
        // blocked driver (it unwinds at its next emit)
        let _ = handle.next_event();
        drop(handle);
    }

    #[test]
    fn multiplexed_session_interleaves_on_one_connection() {
        use crate::util::sync::atomic::Ordering;
        let (server, _repo) = synthetic_server("sess-mux").unwrap();
        let handle = ProgressiveSession::multiplex()
            .addr(server.addr())
            .add_model(FetchRequest::new("alpha"), 4.0)
            .add_model(FetchRequest::new("beta"), 1.0)
            .start()
            .unwrap();
        let mut per_model: HashMap<String, Vec<usize>> = HashMap::new();
        for ev in handle.events() {
            if let SessionEvent::StageComplete { model, stage, .. } = ev {
                per_model.entry(model).or_default().push(stage);
            }
        }
        let report = handle.finish().unwrap();
        assert_eq!(server.stats().connections.load(Ordering::SeqCst), 1);
        assert_eq!(report.requests, 16);
        for name in ["alpha", "beta"] {
            assert_eq!(per_model[name], (0..8).collect::<Vec<_>>(), "{name}");
            assert!(report.assembler(name).unwrap().is_complete(), "{name}");
        }
    }
}
