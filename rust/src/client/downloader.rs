//! Socket download loop: reads chunks, feeds the incremental `.pnet`
//! parser, forwards events. Records byte/stage arrival times.

use std::io::Read;
use std::net::TcpStream;
use std::time::Instant;

use anyhow::Result;

use crate::format::{FrameParser, ParserEvent};
use crate::server::proto::FetchRequest;
use crate::server::service::open_fetch;

/// Download chunk size. Small enough that stage boundaries are observed
/// promptly at paper link speeds, large enough to be cheap.
pub const CHUNK: usize = 8 * 1024;

/// A timestamped parser event.
#[derive(Debug)]
pub struct TimedEvent {
    pub t: f64,
    pub event: ParserEvent,
}

/// Streaming downloader bound to one fetch.
pub struct Downloader {
    stream: TcpStream,
    parser: FrameParser,
    start: Instant,
    pub total_size: u64,
    buf: Vec<u8>,
}

impl Downloader {
    /// Connect and issue the fetch request.
    pub fn connect(addr: &std::net::SocketAddr, req: &FetchRequest) -> Result<Self> {
        let (stream, total_size) = open_fetch(addr, req)?;
        Ok(Self {
            stream,
            parser: FrameParser::new(),
            start: Instant::now(),
            total_size,
            buf: vec![0u8; CHUNK],
        })
    }

    /// Set a small kernel receive buffer so that *not reading* (serial
    /// mode) actually back-pressures the sender, as a busy browser tab
    /// would stall a slow HTTP stream.
    pub fn set_small_recv_buffer(&self) -> Result<()> {
        use std::os::fd::AsRawFd;
        let fd = self.stream.as_raw_fd();
        let size: libc::c_int = 16 * 1024;
        let rc = unsafe {
            libc::setsockopt(
                fd,
                libc::SOL_SOCKET,
                libc::SO_RCVBUF,
                &size as *const _ as *const libc::c_void,
                std::mem::size_of::<libc::c_int>() as libc::socklen_t,
            )
        };
        anyhow::ensure!(rc == 0, "setsockopt(SO_RCVBUF) failed");
        Ok(())
    }

    /// Seconds since the fetch started.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn start_instant(&self) -> Instant {
        self.start
    }

    pub fn bytes_received(&self) -> u64 {
        self.parser.bytes_consumed()
    }

    pub fn is_done(&self) -> bool {
        self.parser.is_done()
    }

    /// Blocking read of the next chunk; returns timestamped events.
    /// Empty vec + `is_done()` signals completion.
    pub fn next_events(&mut self) -> Result<Vec<TimedEvent>> {
        loop {
            if self.parser.is_done() {
                return Ok(Vec::new());
            }
            let n = self.stream.read(&mut self.buf)?;
            if n == 0 {
                anyhow::bail!(
                    "connection closed early at {} / {} bytes",
                    self.parser.bytes_consumed(),
                    self.total_size
                );
            }
            let events = self.parser.feed(&self.buf[..n])?;
            if !events.is_empty() {
                let t = self.elapsed();
                return Ok(events
                    .into_iter()
                    .map(|event| TimedEvent { t, event })
                    .collect());
            }
        }
    }

    /// Drain the entire stream, returning all events (non-progressive
    /// "singleton" download).
    pub fn download_all(&mut self) -> Result<Vec<TimedEvent>> {
        let mut out = Vec::new();
        while !self.is_done() {
            out.extend(self.next_events()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Schedule;
    use crate::server::{Repository, Server};
    use crate::server::service::ServerConfig;
    use std::sync::Arc;

    #[test]
    fn download_all_yields_all_fragments() {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let repo = Arc::new(Repository::open_default().unwrap());
        let server = Server::start("127.0.0.1:0", repo.clone(), ServerConfig::default()).unwrap();
        let mut dl = Downloader::connect(&server.addr(), &FetchRequest::new("mlp")).unwrap();
        let events = dl.download_all().unwrap();
        let m = repo.registry().get("mlp").unwrap();
        let frags = events
            .iter()
            .filter(|e| matches!(e.event, ParserEvent::Fragment { .. }))
            .count();
        assert_eq!(
            frags,
            Schedule::paper_default().stages() * m.tensors.len()
        );
        assert!(dl.is_done());
        assert_eq!(dl.bytes_received(), dl.total_size);
    }

    #[test]
    fn events_are_time_ordered() {
        if !crate::artifacts_available() {
            return;
        }
        let repo = Arc::new(Repository::open_default().unwrap());
        let server = Server::start("127.0.0.1:0", repo, ServerConfig::default()).unwrap();
        let mut dl = Downloader::connect(&server.addr(), &FetchRequest::new("mlp")).unwrap();
        let events = dl.download_all().unwrap();
        for w in events.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
    }
}
