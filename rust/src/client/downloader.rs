//! Socket download loop: reads chunks, feeds the incremental `.pnet`
//! parser, forwards events. Records byte/stage arrival times, and can
//! resume an interrupted fetch at the last complete stage boundary
//! (re-requesting only `stages: boundary..end` — no byte-offset guessing).

#![forbid(unsafe_code)]

use std::io::Read;
use std::net::TcpStream;
use std::time::Instant;
use crate::util::sync::clock;

use anyhow::Result;

use crate::format::{FrameParser, ParserEvent, PnetManifest};
use crate::obs;
use crate::server::proto::FetchRequest;
use crate::server::service::open_fetch;

/// Download chunk size. Small enough that stage boundaries are observed
/// promptly at paper link speeds, large enough to be cheap.
pub const CHUNK: usize = 8 * 1024;

/// A timestamped parser event.
#[derive(Debug)]
pub struct TimedEvent {
    pub t: f64,
    pub event: ParserEvent,
}

/// Streaming downloader bound to one fetch (possibly spanning several
/// connections after stage-boundary resumes).
pub struct Downloader {
    stream: TcpStream,
    parser: FrameParser,
    start: Instant,
    /// bytes of the selected body (the first status frame's `total`)
    pub total_size: u64,
    addr: std::net::SocketAddr,
    req: FetchRequest,
    /// body bytes accounted to earlier connections of a resumed fetch
    base_consumed: u64,
    /// re-apply the small SO_RCVBUF to sockets opened by a resume
    small_recv_buffer: bool,
    /// canonical container byte prefix received so far (for partial-stage
    /// cache persistence); None = capture disabled
    capture: Option<Vec<u8>>,
    buf: Vec<u8>,
}

impl Downloader {
    /// Connect and issue the fetch request. `req.stages` may select a
    /// prefix `0..end`; ranges starting later need [`Downloader::resume_at_stage`].
    pub fn connect(addr: &std::net::SocketAddr, req: &FetchRequest) -> Result<Self> {
        anyhow::ensure!(
            req.offset == 0,
            "Downloader parses from the container start; resume with stage ranges, not offsets"
        );
        if let Some((a, _)) = req.stages {
            anyhow::ensure!(a == 0, "initial fetch cannot start at stage {a}; use resume_at_stage");
        }
        // The download loop may run on its own thread, so the span parent
        // comes from the request's wire context, not the TLS stack.
        let conn_span = req.trace.map(|ctx| obs::begin_child("client.connect", ctx));
        let (stream, resp) = open_fetch(addr, req)?;
        if let Some(mut sp) = conn_span {
            sp.attr("total", resp.total);
            sp.end();
        }
        // The server may clamp the requested window (degrade-mode load
        // shedding under `fleet::admission`); the echoed range in the
        // status frame is authoritative, so build the parser from it and
        // expect exactly the bytes that will arrive.
        let parser = match resp.stages.or(req.stages) {
            None => FrameParser::new(),
            Some((0, b)) => FrameParser::for_stage_prefix(b as usize),
            Some((a, _)) => anyhow::bail!(
                "server answered the initial fetch with a window starting at stage {a}"
            ),
        };
        // Adopt a clamped window wholesale: stage-boundary resumes must
        // stay inside it (resuming to the *original* end would bypass
        // the shed and corrupt the byte accounting).
        let mut req = req.clone();
        if resp.stages != req.stages {
            if let Some((0, b)) = resp.stages {
                req.stages = Some((0, b));
            }
        }
        Ok(Self {
            stream,
            parser,
            start: clock::now(),
            total_size: resp.total,
            addr: *addr,
            req,
            base_consumed: 0,
            small_recv_buffer: false,
            capture: None,
            buf: vec![0u8; CHUNK],
        })
    }

    /// Reconnect a fetch whose prefix (preamble + stages `0..start_stage`)
    /// is already held locally — the cache-aware resume path. Issues a
    /// `stages: start_stage..end` request; `manifest` comes from the
    /// locally held prefix and `bytes_already` is that prefix's length
    /// (counted into [`Downloader::bytes_received`] / progress).
    pub fn connect_resumed(
        addr: &std::net::SocketAddr,
        req: &FetchRequest,
        manifest: PnetManifest,
        start_stage: usize,
        bytes_already: u64,
    ) -> Result<Self> {
        anyhow::ensure!(
            req.offset == 0 && req.stages.is_none(),
            "cache resume takes a whole-container request"
        );
        let stages = manifest.schedule.stages();
        anyhow::ensure!(
            start_stage > 0 && start_stage < stages,
            "resume stage {start_stage} out of range (1..{stages})"
        );
        let parser = FrameParser::resume(manifest, start_stage, Some(stages))?;
        let wire_req = req
            .clone()
            .with_stages(start_stage as u32, stages as u32);
        let conn_span = wire_req.trace.map(|ctx| obs::begin_child("client.connect", ctx));
        let (stream, resp) = open_fetch(addr, &wire_req)?;
        if let Some(mut sp) = conn_span {
            sp.attr("resume_stage", start_stage);
            sp.end();
        }
        Ok(Self {
            stream,
            parser,
            start: clock::now(),
            total_size: bytes_already + resp.remaining,
            addr: *addr,
            req: wire_req,
            base_consumed: bytes_already,
            small_recv_buffer: false,
            capture: None,
            buf: vec![0u8; CHUNK],
        })
    }

    /// Start recording the canonical container byte prefix, seeded with
    /// bytes already held (empty for a fresh fetch). A stage-boundary
    /// resume truncates the record back to the boundary, so it always
    /// reflects an exact byte prefix of the container — suitable for
    /// partial-download cache persistence.
    pub fn enable_capture(&mut self, seed: Vec<u8>) {
        self.capture = Some(seed);
    }

    /// The captured canonical byte prefix, if capture is enabled.
    pub fn captured(&self) -> Option<&[u8]> {
        self.capture.as_deref()
    }

    /// Take ownership of the captured prefix (disables further capture).
    pub fn take_captured(&mut self) -> Option<Vec<u8>> {
        self.capture.take()
    }

    /// Set a small kernel receive buffer so that *not reading* (serial
    /// mode) actually back-pressures the sender, as a busy browser tab
    /// would stall a slow HTTP stream. Sticky: sockets opened by a later
    /// [`Downloader::resume_at_stage`] get the same treatment.
    pub fn set_small_recv_buffer(&mut self) -> Result<()> {
        crate::util::sys::shrink_recv_buffer(&self.stream)?;
        self.small_recv_buffer = true;
        Ok(())
    }

    /// Seconds since the fetch started.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn start_instant(&self) -> Instant {
        self.start
    }

    /// Body bytes received across all connections of this fetch.
    pub fn bytes_received(&self) -> u64 {
        self.base_consumed + self.parser.bytes_consumed()
    }

    /// Fraction of the selected body received, using the server's
    /// advertised sizes (correct under offset and stage-range resumes).
    pub fn progress(&self) -> f64 {
        if self.total_size == 0 {
            1.0
        } else {
            (self.bytes_received() as f64 / self.total_size as f64).min(1.0)
        }
    }

    pub fn is_done(&self) -> bool {
        self.parser.is_done()
    }

    /// True once the manifest arrived — the precondition for resuming at
    /// a stage boundary.
    pub fn can_resume(&self) -> bool {
        self.parser.manifest().is_some()
    }

    /// Last fully parsed stage boundary (absolute stage count).
    pub fn stage_boundary(&self) -> usize {
        self.parser.stage_boundary()
    }

    /// Reconnect and continue the fetch from `stage` (a completed stage
    /// boundary, usually [`Downloader::stage_boundary`]). The new request
    /// asks for `stages: stage..end`, so the server skips everything
    /// already delivered; fragments of a partially received stage are
    /// re-sent and deduplicated by the assembler.
    pub fn resume_at_stage(&mut self, stage: usize) -> Result<()> {
        let manifest = self
            .parser
            .manifest()
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("cannot resume before the manifest arrived"))?;
        let end = match self.req.stages {
            Some((_, b)) => b as usize,
            None => manifest.schedule.stages(),
        };
        anyhow::ensure!(stage < end, "resume stage {stage} not before window end {end}");
        // stage ranges are self-describing: never combine with a byte offset
        let req = self
            .req
            .clone()
            .with_offset(0)
            .with_stages(stage as u32, end as u32);
        let conn_span = req.trace.map(|ctx| obs::begin_child("client.connect", ctx));
        let (stream, resp) = open_fetch(&self.addr, &req)?;
        if let Some(mut sp) = conn_span {
            sp.attr("resume_stage", stage);
            sp.end();
        }
        // A stage-0 resume is an *initial* window again, so a degraded
        // server may clamp it; the echoed range stays authoritative here
        // too (mid-container resumes pass through unclamped).
        let mut end = end;
        if let Some((0, b)) = resp.stages {
            if stage == 0 && (b as usize) < end {
                end = b as usize;
                self.req.stages = Some((0, b));
                self.total_size = resp.total;
            }
        }
        if self.small_recv_buffer {
            let _ = crate::util::sys::shrink_recv_buffer(&stream);
        }
        if let Some(cap) = &mut self.capture {
            // keep the record a canonical byte prefix: drop any bytes of
            // the partially received stage (they will be re-sent)
            if stage == 0 {
                cap.clear();
            } else {
                let len = manifest
                    .stage_index()
                    .body_range(Some((0, stage as u32)))?
                    .end;
                cap.truncate(len);
            }
        }
        self.parser = if stage == 0 {
            // the manifest never fully arrived or stage 0 is incomplete:
            // the range re-includes the preamble
            FrameParser::for_stage_prefix(end)
        } else {
            FrameParser::resume(manifest, stage, Some(end))?
        };
        // account the skipped prefix exactly: the server tells us how
        // many bytes are left of the selected body
        self.base_consumed = self.total_size.saturating_sub(resp.remaining);
        self.stream = stream;
        Ok(())
    }

    /// Blocking read of the next chunk; returns timestamped events.
    /// Empty vec + `is_done()` signals completion.
    pub fn next_events(&mut self) -> Result<Vec<TimedEvent>> {
        loop {
            if self.parser.is_done() {
                return Ok(Vec::new());
            }
            let n = self.stream.read(&mut self.buf)?;
            if n == 0 {
                anyhow::bail!(
                    "connection closed early at {} / {} bytes",
                    self.bytes_received(),
                    self.total_size
                );
            }
            if let Some(cap) = &mut self.capture {
                cap.extend_from_slice(&self.buf[..n]);
            }
            let events = self.parser.feed(&self.buf[..n])?;
            if !events.is_empty() {
                let t = self.elapsed();
                return Ok(events
                    .into_iter()
                    .map(|event| TimedEvent { t, event })
                    .collect());
            }
        }
    }

    /// Drain the entire stream, returning all events (non-progressive
    /// "singleton" download).
    pub fn download_all(&mut self) -> Result<Vec<TimedEvent>> {
        let mut out = Vec::new();
        while !self.is_done() {
            out.extend(self.next_events()?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Assembler;
    use crate::models::Registry;
    use crate::quant::Schedule;
    use crate::server::service::ServerConfig;
    use crate::server::{Repository, Server};
    use crate::testutil::fixture::{fixture_root, write_index, write_model};
    use crate::util::sync::Arc;

    fn synthetic_server(tag: &str) -> (Server, Arc<Repository>) {
        crate::testutil::fixture::synthetic_server(tag).unwrap()
    }

    /// Server with one 40 000-param model whose per-stage frame (~10 KB)
    /// exceeds the 8 KB read chunk, so `stage_boundary()` can only ever
    /// advance one stage per `next_events` call — no timing races.
    fn big_model_server(tag: &str) -> (Server, Arc<Repository>) {
        let root = fixture_root(tag);
        let _ = std::fs::remove_dir_all(&root);
        let models_dir = root.join("models");
        std::fs::create_dir_all(&models_dir).unwrap();
        write_model(&models_dir, "gamma", &[("w", &[200, 200][..])], 0xB16).unwrap();
        write_index(&models_dir, &["gamma"]).unwrap();
        let repo = Arc::new(Repository::new(Registry::open(&root).unwrap()));
        let server = Server::start("127.0.0.1:0", repo.clone(), ServerConfig::default()).unwrap();
        (server, repo)
    }

    #[test]
    fn download_all_yields_all_fragments() {
        let (server, repo) = synthetic_server("dl-all");
        let mut dl = Downloader::connect(&server.addr(), &FetchRequest::new("alpha")).unwrap();
        let events = dl.download_all().unwrap();
        let m = repo.registry().get("alpha").unwrap();
        let frags = events
            .iter()
            .filter(|e| matches!(e.event, ParserEvent::Fragment { .. }))
            .count();
        assert_eq!(frags, Schedule::paper_default().stages() * m.tensors.len());
        assert!(dl.is_done());
        assert_eq!(dl.bytes_received(), dl.total_size);
        assert!((dl.progress() - 1.0).abs() < 1e-12);
        assert_eq!(dl.stage_boundary(), 8);
    }

    #[test]
    fn events_are_time_ordered() {
        let (server, _repo) = synthetic_server("dl-ordered");
        let mut dl = Downloader::connect(&server.addr(), &FetchRequest::new("alpha")).unwrap();
        let events = dl.download_all().unwrap();
        for w in events.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
    }

    #[test]
    fn stage_prefix_fetch_stops_at_window() {
        let (server, repo) = synthetic_server("dl-prefix");
        let req = FetchRequest::new("alpha").with_stages(0, 3);
        let mut dl = Downloader::connect(&server.addr(), &req).unwrap();
        let events = dl.download_all().unwrap();
        assert!(dl.is_done());
        assert_eq!(dl.stage_boundary(), 3);
        let m = repo.registry().get("alpha").unwrap();
        let frags = events
            .iter()
            .filter(|e| matches!(e.event, ParserEvent::Fragment { .. }))
            .count();
        assert_eq!(frags, 3 * m.tensors.len());
    }

    #[test]
    fn mid_fetch_resume_reconstructs_identically() {
        // Pull events until two stages complete, then abandon the
        // connection and resume at the boundary; the assembled codes must
        // match an uninterrupted fetch. Uses the big-model fixture so a
        // single read can never complete more than one stage (the whole
        // container of a small model fits in one chunk, which would race
        // the loop below straight to stage 8).
        let (server, _repo) = big_model_server("dl-resume");
        let req = FetchRequest::new("gamma");

        // uninterrupted reference
        let mut dl_ref = Downloader::connect(&server.addr(), &req).unwrap();
        let mut asm_ref: Option<Assembler> = None;
        for te in dl_ref.download_all().unwrap() {
            match te.event {
                ParserEvent::Manifest(m) => asm_ref = Some(Assembler::new(*m)),
                ParserEvent::Fragment {
                    stage,
                    tensor,
                    payload,
                } => {
                    asm_ref
                        .as_mut()
                        .unwrap()
                        .absorb(stage, tensor, &payload)
                        .unwrap();
                }
            }
        }
        let asm_ref = asm_ref.unwrap();

        // interrupted + resumed fetch
        let mut dl = Downloader::connect(&server.addr(), &req).unwrap();
        let mut asm: Option<Assembler> = None;
        while dl.stage_boundary() < 2 {
            for te in dl.next_events().unwrap() {
                match te.event {
                    ParserEvent::Manifest(m) => asm = Some(Assembler::new(*m)),
                    ParserEvent::Fragment {
                        stage,
                        tensor,
                        payload,
                    } => {
                        asm.as_mut().unwrap().absorb(stage, tensor, &payload).unwrap();
                    }
                }
            }
        }
        let boundary = dl.stage_boundary();
        dl.resume_at_stage(boundary).unwrap();
        while !dl.is_done() {
            for te in dl.next_events().unwrap() {
                if let ParserEvent::Fragment {
                    stage,
                    tensor,
                    payload,
                } = te.event
                {
                    asm.as_mut().unwrap().absorb(stage, tensor, &payload).unwrap();
                }
            }
        }
        let asm = asm.unwrap();
        assert!(asm.is_complete());
        assert_eq!(asm.codes_flat(), asm_ref.codes_flat());
        // progress accounting stays exact across the resume
        assert_eq!(dl.bytes_received(), dl.total_size);
    }

    #[test]
    fn capture_stays_canonical_across_resume() {
        let (server, repo) = big_model_server("dl-capture");
        let req = FetchRequest::new("gamma");
        let mut dl = Downloader::connect(&server.addr(), &req).unwrap();
        dl.enable_capture(Vec::new());
        while dl.stage_boundary() < 2 {
            dl.next_events().unwrap();
        }
        // abandon the connection mid-stage; the resume truncates the
        // capture back to the boundary before appending the re-sent frames
        let boundary = dl.stage_boundary();
        dl.resume_at_stage(boundary).unwrap();
        while !dl.is_done() {
            dl.next_events().unwrap();
        }
        let expect = repo
            .container("gamma", &Schedule::paper_default())
            .unwrap();
        let cap = dl.take_captured().unwrap();
        assert_eq!(&cap[..], &expect[..]);
        assert!(dl.captured().is_none(), "take_captured disables capture");
    }

    #[test]
    fn connect_resumed_completes_a_cached_prefix() {
        use crate::format::PnetReader;
        let (server, repo) = big_model_server("dl-connect-resumed");
        let req = FetchRequest::new("gamma");
        let full = repo
            .container("gamma", &Schedule::paper_default())
            .unwrap();
        let r = PnetReader::from_bytes(&full).unwrap();
        let idx = r.manifest.stage_index();
        // pretend stages 0..3 were already cached locally
        let prefix_len = idx.body_range(Some((0, 3))).unwrap().end;
        let mut dl = Downloader::connect_resumed(
            &server.addr(),
            &req,
            r.manifest.clone(),
            3,
            prefix_len as u64,
        )
        .unwrap();
        dl.enable_capture(full[..prefix_len].to_vec());
        let mut frags = 0;
        while !dl.is_done() {
            for te in dl.next_events().unwrap() {
                if let ParserEvent::Fragment { stage, .. } = te.event {
                    assert!(stage >= 3, "resumed stream re-sent stage {stage}");
                    frags += 1;
                }
            }
        }
        assert_eq!(frags, (8 - 3) * r.manifest.tensors.len());
        assert_eq!(dl.bytes_received(), dl.total_size);
        // seed + resumed bytes reassemble the exact container
        assert_eq!(&dl.take_captured().unwrap()[..], &full[..]);
    }
}
