//! Pipelined multi-model delivery, now a thin adapter over a multiplexed
//! [`session::ProgressiveSession`](super::session::ProgressiveSession).
//!
//! One connection, many stage-range requests, interleaved across models
//! by the coordinator's weighted-fair plan
//! ([`crate::coordinator::scheduler::interleave_stages`]). The whole-body
//! protocol structurally could not express this: it is what the
//! stage-range extension buys. The mechanics live in the session driver;
//! [`MultiplexClient::fetch_interleaved`] merely drains the event stream
//! and repackages the report. New code should build the session directly
//! (`ProgressiveSession::multiplex()`) to observe per-stage events and
//! bind runtimes for mid-download serving of every model.

#![forbid(unsafe_code)]

use std::collections::HashMap;

use anyhow::Result;

use super::assembler::Assembler;
use super::session::ProgressiveSession;
use crate::quant::Schedule;
use crate::server::proto::FetchRequest;

/// One model of an interleaved fetch.
#[derive(Debug, Clone)]
pub struct MultiplexModel {
    pub model: String,
    /// None = server default schedule
    pub schedule: Option<Schedule>,
    /// relative bandwidth share (> 0)
    pub priority: f64,
}

impl MultiplexModel {
    pub fn new(model: &str) -> Self {
        Self {
            model: model.to_string(),
            schedule: None,
            priority: 1.0,
        }
    }

    pub fn with_priority(mut self, priority: f64) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    fn request(&self) -> FetchRequest {
        let mut req = FetchRequest::new(&self.model);
        if let Some(s) = &self.schedule {
            req = req.with_schedule(s.clone());
        }
        req
    }
}

/// Outcome of an interleaved fetch: fully assembled models plus transfer
/// accounting.
pub struct MultiplexOutcome {
    /// model name → assembler holding every stage's codes
    pub assemblers: HashMap<String, Assembler>,
    /// total body bytes received
    pub bytes: u64,
    /// stage-range requests issued (all on one connection)
    pub requests: usize,
    /// the executed (model, stage) order, for tests and timelines
    pub order: Vec<(String, usize)>,
}

/// Blocking client fetching several models over one connection,
/// stage-interleaved.
#[deprecated(
    since = "0.3.0",
    note = "use client::session::ProgressiveSession::multiplex — builder, \
            typed event stream, and per-model ApproxModel handles"
)]
pub struct MultiplexClient {
    addr: std::net::SocketAddr,
}

#[allow(deprecated)]
impl MultiplexClient {
    pub fn new(addr: std::net::SocketAddr) -> Self {
        Self { addr }
    }

    /// Fetch all stages of `models`, interleaved by weighted-fair
    /// priority, over a single keep-alive connection.
    pub fn fetch_interleaved(&self, models: &[MultiplexModel]) -> Result<MultiplexOutcome> {
        let mut builder = ProgressiveSession::multiplex().addr(self.addr);
        for m in models {
            builder = builder.add_model(m.request(), m.priority);
        }
        let report = builder.start()?.run()?;
        Ok(MultiplexOutcome {
            assemblers: report.assemblers,
            bytes: report.summary.bytes,
            requests: report.requests,
            order: report.order,
        })
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::format::PnetReader;
    use crate::testutil::fixture::synthetic_server;
    use crate::util::sync::atomic::Ordering;

    #[test]
    fn two_models_interleaved_on_one_connection() {
        let (server, repo) = synthetic_server("mux-two").unwrap();
        let client = MultiplexClient::new(server.addr());
        let out = client
            .fetch_interleaved(&[
                MultiplexModel::new("alpha").with_priority(4.0),
                MultiplexModel::new("beta"),
            ])
            .unwrap();

        // one connection, 2 + 2×7 requests
        assert_eq!(server.stats().connections.load(Ordering::SeqCst), 1);
        assert_eq!(out.requests, 16);
        // stages genuinely interleave: beta stages appear between alphas
        let alpha_last = out.order.iter().rposition(|(m, _)| m == "alpha").unwrap();
        let beta_first_late = out
            .order
            .iter()
            .position(|(m, s)| m == "beta" && *s >= 1)
            .unwrap();
        assert!(beta_first_late < alpha_last, "{:?}", out.order);

        // each model reassembles byte-for-byte like a direct decode of
        // the cached container
        for name in ["alpha", "beta"] {
            let asm = &out.assemblers[name];
            assert!(asm.is_complete(), "{name} incomplete");
            let container = repo
                .container(name, &Schedule::paper_default())
                .unwrap();
            let r = PnetReader::from_bytes(&container).unwrap();
            let mut direct = Assembler::new(r.manifest.clone());
            for s in 0..r.manifest.schedule.stages() {
                for t in 0..r.manifest.tensors.len() {
                    direct.absorb(s, t, &r.fragments[s][t]).unwrap();
                }
            }
            assert_eq!(asm.codes_flat(), direct.codes_flat(), "{name}");
        }
    }

    #[test]
    fn priority_shapes_delivery_order() {
        let (server, _repo) = synthetic_server("mux-prio").unwrap();
        let client = MultiplexClient::new(server.addr());
        let out = client
            .fetch_interleaved(&[
                MultiplexModel::new("alpha").with_priority(0.25),
                MultiplexModel::new("beta").with_priority(4.0),
            ])
            .unwrap();
        // beta (high priority) completes before alpha despite being
        // requested second
        let beta_done = out.order.iter().rposition(|(m, _)| m == "beta").unwrap();
        let alpha_done = out.order.iter().rposition(|(m, _)| m == "alpha").unwrap();
        assert!(beta_done < alpha_done, "{:?}", out.order);
    }
}
