//! Pipelined multi-model delivery: ONE connection, many stage-range
//! requests, interleaved across models by the coordinator's weighted-fair
//! plan ([`crate::coordinator::scheduler::interleave_stages`]).
//!
//! Phase 1 fetches stage 0 of every model (yielding each manifest, hence
//! each stage's exact wire size); phase 2 requests the remaining stages
//! one at a time in plan order, keeping the connection alive between
//! requests. The whole-body protocol structurally could not express this:
//! it is what the stage-range extension buys.

use std::collections::HashMap;
use std::io::Read;
use std::net::TcpStream;

use anyhow::{Context, Result};

use super::assembler::Assembler;
use crate::coordinator::scheduler::{interleave_stages, InterleaveModel};
use crate::format::{FrameParser, ParserEvent};
use crate::quant::Schedule;
use crate::server::proto::FetchRequest;
use crate::server::service::request_on;

/// One model of an interleaved fetch.
#[derive(Debug, Clone)]
pub struct MultiplexModel {
    pub model: String,
    /// None = server default schedule
    pub schedule: Option<Schedule>,
    /// relative bandwidth share (> 0)
    pub priority: f64,
}

impl MultiplexModel {
    pub fn new(model: &str) -> Self {
        Self {
            model: model.to_string(),
            schedule: None,
            priority: 1.0,
        }
    }

    pub fn with_priority(mut self, priority: f64) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }
}

/// Outcome of an interleaved fetch: fully assembled models plus transfer
/// accounting.
pub struct MultiplexOutcome {
    /// model name → assembler holding every stage's codes
    pub assemblers: HashMap<String, Assembler>,
    /// total body bytes received
    pub bytes: u64,
    /// stage-range requests issued (all on one connection)
    pub requests: usize,
    /// the executed (model, stage) order, for tests and timelines
    pub order: Vec<(String, usize)>,
}

/// Client fetching several models over one connection, stage-interleaved.
pub struct MultiplexClient {
    addr: std::net::SocketAddr,
}

impl MultiplexClient {
    pub fn new(addr: std::net::SocketAddr) -> Self {
        Self { addr }
    }

    /// Fetch all stages of `models`, interleaved by weighted-fair
    /// priority, over a single keep-alive connection.
    pub fn fetch_interleaved(&self, models: &[MultiplexModel]) -> Result<MultiplexOutcome> {
        anyhow::ensure!(!models.is_empty(), "no models requested");
        let mut seen = std::collections::HashSet::new();
        for m in models {
            anyhow::ensure!(
                seen.insert(m.model.as_str()),
                "duplicate model '{}' in interleaved fetch",
                m.model
            );
        }
        let mut stream = TcpStream::connect(self.addr)
            .with_context(|| format!("connecting {}", self.addr))?;
        stream.set_nodelay(true)?;

        let mut assemblers: HashMap<String, Assembler> = HashMap::new();
        let mut parsers: HashMap<String, FrameParser> = HashMap::new();
        let mut bytes = 0u64;
        let mut requests = 0usize;
        let mut order: Vec<(String, usize)> = Vec::new();

        // Phase 1: stage 0 of every model — the manifest arrives with it,
        // so stage sizes become known and the rest can be planned.
        for m in models {
            let req = base_request(m).with_stages(0, 1).with_keep_alive(true);
            let resp = request_on(&mut stream, &req)?;
            let mut parser = FrameParser::for_stage_prefix(1);
            let events = read_body(&mut stream, resp.remaining, &mut parser)?;
            anyhow::ensure!(parser.is_done(), "stage 0 of {} incomplete", m.model);
            bytes += resp.remaining;
            requests += 1;
            order.push((m.model.clone(), 0));
            for ev in events {
                match ev {
                    ParserEvent::Manifest(man) => {
                        assemblers.insert(m.model.clone(), Assembler::new(*man));
                    }
                    ParserEvent::Fragment {
                        stage,
                        tensor,
                        payload,
                    } => {
                        assemblers
                            .get_mut(&m.model)
                            .context("manifest precedes fragments")?
                            .absorb(stage, tensor, &payload)?;
                    }
                }
            }
            // the parser keeps the manifest; later windows reuse it
            parsers.insert(m.model.clone(), parser);
        }

        // Phase 2: weighted-fair plan over the remaining stages.
        let metas: Vec<InterleaveModel> = models
            .iter()
            .map(|m| {
                let man = parsers[&m.model]
                    .manifest()
                    .context("phase 1 always parses the manifest")?;
                let idx = man.stage_index();
                let stage_bytes: Vec<u64> = (1..man.schedule.stages())
                    .map(|s| idx.stage_span(s, s + 1).map(|r| r.len() as u64))
                    .collect::<Result<_>>()?;
                Ok(InterleaveModel {
                    name: m.model.clone(),
                    first_stage: 1,
                    stage_bytes,
                    priority: m.priority,
                })
            })
            .collect::<Result<_>>()?;
        let plan = interleave_stages(&metas);

        for (i, entry) in plan.iter().enumerate() {
            let m = models
                .iter()
                .find(|m| m.model == entry.model)
                .expect("plan only contains requested models");
            let keep = i + 1 < plan.len();
            let req = base_request(m)
                .with_stages(entry.stage as u32, entry.stage as u32 + 1)
                .with_keep_alive(keep);
            let resp = request_on(&mut stream, &req)?;
            let parser = parsers
                .get_mut(&entry.model)
                .expect("parser created in phase 1");
            parser.rewindow(entry.stage, entry.stage + 1)?;
            let events = read_body(&mut stream, resp.remaining, parser)?;
            anyhow::ensure!(
                parser.is_done(),
                "stage {} of {} incomplete",
                entry.stage,
                entry.model
            );
            bytes += resp.remaining;
            requests += 1;
            order.push((entry.model.clone(), entry.stage));
            for ev in events {
                if let ParserEvent::Fragment {
                    stage,
                    tensor,
                    payload,
                } = ev
                {
                    assemblers
                        .get_mut(&entry.model)
                        .expect("assembler created in phase 1")
                        .absorb(stage, tensor, &payload)?;
                }
            }
        }

        Ok(MultiplexOutcome {
            assemblers,
            bytes,
            requests,
            order,
        })
    }
}

fn base_request(m: &MultiplexModel) -> FetchRequest {
    let mut req = FetchRequest::new(&m.model);
    if let Some(s) = &m.schedule {
        req = req.with_schedule(s.clone());
    }
    req
}

/// Read exactly `remaining` body bytes (never more — the next response's
/// status frame follows on the same stream) and feed them to the parser.
fn read_body(
    stream: &mut TcpStream,
    remaining: u64,
    parser: &mut FrameParser,
) -> Result<Vec<ParserEvent>> {
    let mut events = Vec::new();
    let mut left = remaining as usize;
    let mut buf = [0u8; 8192];
    while left > 0 {
        let want = left.min(buf.len());
        let n = stream.read(&mut buf[..want])?;
        anyhow::ensure!(n > 0, "connection closed with {left} body bytes left");
        events.extend(parser.feed(&buf[..n])?);
        left -= n;
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::PnetReader;
    use crate::testutil::fixture::synthetic_server;
    use std::sync::atomic::Ordering;

    #[test]
    fn two_models_interleaved_on_one_connection() {
        let (server, repo) = synthetic_server("mux-two").unwrap();
        let client = MultiplexClient::new(server.addr());
        let out = client
            .fetch_interleaved(&[
                MultiplexModel::new("alpha").with_priority(4.0),
                MultiplexModel::new("beta"),
            ])
            .unwrap();

        // one connection, 2 + 2×7 requests
        assert_eq!(server.stats().connections.load(Ordering::SeqCst), 1);
        assert_eq!(out.requests, 16);
        // stages genuinely interleave: beta stages appear between alphas
        let alpha_last = out.order.iter().rposition(|(m, _)| m == "alpha").unwrap();
        let beta_first_late = out
            .order
            .iter()
            .position(|(m, s)| m == "beta" && *s >= 1)
            .unwrap();
        assert!(beta_first_late < alpha_last, "{:?}", out.order);

        // each model reassembles byte-for-byte like a direct decode of
        // the cached container
        for name in ["alpha", "beta"] {
            let asm = &out.assemblers[name];
            assert!(asm.is_complete(), "{name} incomplete");
            let container = repo
                .container(name, &Schedule::paper_default())
                .unwrap();
            let r = PnetReader::from_bytes(&container).unwrap();
            let mut direct = Assembler::new(r.manifest.clone());
            for s in 0..r.manifest.schedule.stages() {
                for t in 0..r.manifest.tensors.len() {
                    direct.absorb(s, t, &r.fragments[s][t]).unwrap();
                }
            }
            assert_eq!(asm.codes_flat(), direct.codes_flat(), "{name}");
        }
    }

    #[test]
    fn priority_shapes_delivery_order() {
        let (server, _repo) = synthetic_server("mux-prio").unwrap();
        let client = MultiplexClient::new(server.addr());
        let out = client
            .fetch_interleaved(&[
                MultiplexModel::new("alpha").with_priority(0.25),
                MultiplexModel::new("beta").with_priority(4.0),
            ])
            .unwrap();
        // beta (high priority) completes before alpha despite being
        // requested second
        let beta_done = out.order.iter().rposition(|(m, _)| m == "beta").unwrap();
        let alpha_done = out.order.iter().rposition(|(m, _)| m == "alpha").unwrap();
        assert!(beta_done < alpha_done, "{:?}", out.order);
    }
}
