//! The progressive transmission + inference pipeline (Fig 1, right half;
//! Fig 4 timelines).
//!
//! Two execution modes:
//! - [`ExecMode::Serial`] — "w/o concurrent" in Table I: reconstruct +
//!   inference run inline on the download thread; the socket is not read
//!   meanwhile (a small SO_RCVBUF makes the sender actually stall, like a
//!   single-threaded JS client would stall an HTTP stream).
//! - [`ExecMode::Concurrent`] — §III-C: the download thread only parses
//!   frames and forwards them; a worker thread assembles, reconstructs
//!   and infers while the transfer keeps flowing. With inference shorter
//!   than the inter-stage transfer gap, total time equals the singleton
//!   transfer (the paper's +0% column).

use std::time::Instant;

use anyhow::Result;

use super::assembler::Assembler;
use super::downloader::{Downloader, TimedEvent};
use crate::format::ParserEvent;
use crate::metrics::{EventKind, Timeline};
use crate::runtime::{InferOutput, ModelSession};
use crate::server::proto::FetchRequest;
use crate::util::pool::BoundedQueue;

/// Serial (paper "w/o concurrent") vs concurrent (§III-C) execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    Serial,
    Concurrent,
}

/// Which completed stages trigger an inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferencePolicy {
    /// Infer at every completed stage (the paper's 2→4→…→16 run).
    EveryStage,
    /// Skip to the newest complete stage when inference lags the link.
    LatestOnly,
    /// Only infer once the final stage arrived (singleton behaviour).
    FinalOnly,
}

/// Options for a progressive fetch.
#[derive(Debug, Clone)]
pub struct ProgressiveOptions {
    pub mode: ExecMode,
    pub policy: InferencePolicy,
    pub request: FetchRequest,
    /// On a dropped connection, reconnect at the last complete stage
    /// boundary up to this many times (0 = fail fast, the old behaviour).
    pub resume_retries: usize,
}

impl ProgressiveOptions {
    pub fn concurrent(model: &str) -> Self {
        Self {
            mode: ExecMode::Concurrent,
            policy: InferencePolicy::EveryStage,
            request: FetchRequest::new(model),
            resume_retries: 2,
        }
    }

    pub fn serial(model: &str) -> Self {
        Self {
            mode: ExecMode::Serial,
            policy: InferencePolicy::EveryStage,
            request: FetchRequest::new(model),
            resume_retries: 2,
        }
    }
}

/// Pull the next event batch, transparently resuming at the last complete
/// stage boundary when the connection drops and retries remain. The
/// assembler deduplicates any re-delivered fragments of a partial stage.
fn next_events_resuming(dl: &mut Downloader, retries_left: &mut usize) -> Result<Vec<TimedEvent>> {
    loop {
        match dl.next_events() {
            Ok(events) => return Ok(events),
            Err(e) => {
                // a failed reconnect (e.g. the outage that dropped the
                // stream is still ongoing) also spends a retry rather than
                // aborting the session while budget remains
                let mut last = e;
                loop {
                    if *retries_left == 0 || !dl.can_resume() {
                        return Err(last);
                    }
                    *retries_left -= 1;
                    let boundary = dl.stage_boundary();
                    crate::log_warn!(
                        "download interrupted ({last:#}); resuming at stage {boundary}"
                    );
                    match dl.resume_at_stage(boundary) {
                        Ok(()) => break,
                        Err(re) => last = re,
                    }
                }
            }
        }
    }
}

/// One intermediate (or final) inference result.
#[derive(Debug, Clone)]
pub struct StageResult {
    pub stage: usize,
    pub cum_bits: u32,
    pub output: InferOutput,
    /// seconds since fetch start when the stage's bytes had arrived
    pub t_transfer_done: f64,
    /// seconds since fetch start when this result became visible
    pub t_output_ready: f64,
}

/// Outcome of a full progressive session.
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    pub results: Vec<StageResult>,
    /// wall time until the last byte arrived
    pub t_transfer_complete: f64,
    /// wall time until the last output was shown (the paper's "total
    /// execution time")
    pub t_total: f64,
    pub bytes: u64,
    pub timeline: Timeline,
}

/// Progressive model client.
pub struct ProgressiveClient {
    addr: std::net::SocketAddr,
}

impl ProgressiveClient {
    pub fn new(addr: std::net::SocketAddr) -> Self {
        Self { addr }
    }

    /// Fetch `opts.request.model` and run inference on `images` (n
    /// samples) at every stage dictated by the policy.
    pub fn fetch_and_infer(
        &self,
        opts: &ProgressiveOptions,
        session: &ModelSession,
        images: &[f32],
        n: usize,
    ) -> Result<SessionOutcome> {
        match opts.mode {
            ExecMode::Serial => self.run_serial(opts, session, images, n),
            ExecMode::Concurrent => self.run_concurrent(opts, session, images, n),
        }
    }

    fn run_serial(
        &self,
        opts: &ProgressiveOptions,
        session: &ModelSession,
        images: &[f32],
        n: usize,
    ) -> Result<SessionOutcome> {
        let mut dl = Downloader::connect(&self.addr, &opts.request)?;
        let _ = dl.set_small_recv_buffer();
        let start = dl.start_instant();
        let mut timeline = Timeline::new();
        timeline.push(0.0, 0, EventKind::StageTransferStart);
        let mut asm: Option<Assembler> = None;
        let mut results = Vec::new();
        let mut t_transfer_complete = 0.0;
        let mut retries_left = opts.resume_retries;

        while !dl.is_done() {
            for TimedEvent { t, event } in next_events_resuming(&mut dl, &mut retries_left)? {
                match event {
                    ParserEvent::Manifest(m) => {
                        asm = Some(Assembler::new(*m));
                    }
                    ParserEvent::Fragment {
                        stage,
                        tensor,
                        payload,
                    } => {
                        let asm = asm.as_mut().expect("manifest precedes fragments");
                        if let Some(done_stage) = asm.absorb(stage, tensor, &payload)? {
                            timeline.push(t, done_stage, EventKind::StageTransferDone);
                            t_transfer_complete = t;
                            if should_infer(opts.policy, done_stage, asm) {
                                // Serial: block the download thread.
                                let r = reconstruct_and_infer(
                                    asm, session, images, n, start, &mut timeline, t,
                                )?;
                                results.push(r);
                            }
                            if done_stage + 1 < asm.manifest().schedule.stages() {
                                timeline.push(t, done_stage + 1, EventKind::StageTransferStart);
                            }
                        }
                    }
                }
            }
        }
        let t_total = results
            .last()
            .map(|r: &StageResult| r.t_output_ready)
            .unwrap_or(t_transfer_complete)
            .max(t_transfer_complete);
        Ok(SessionOutcome {
            results,
            t_transfer_complete,
            t_total,
            bytes: dl.bytes_received(),
            timeline,
        })
    }

    fn run_concurrent(
        &self,
        opts: &ProgressiveOptions,
        session: &ModelSession,
        images: &[f32],
        n: usize,
    ) -> Result<SessionOutcome> {
        let mut dl = Downloader::connect(&self.addr, &opts.request)?;
        let start = dl.start_instant();
        let queue: BoundedQueue<TimedEvent> = BoundedQueue::new(1024);
        let policy = opts.policy;
        let resume_retries = opts.resume_retries;

        std::thread::scope(|scope| -> Result<SessionOutcome> {
            // ---- download thread: read + parse + forward only
            let q_prod = queue.clone();
            let downloader = scope.spawn(move || -> Result<(f64, u64)> {
                let mut run = || -> Result<(f64, u64)> {
                    let mut t_last = 0.0;
                    let mut retries_left = resume_retries;
                    while !dl.is_done() {
                        for te in next_events_resuming(&mut dl, &mut retries_left)? {
                            t_last = te.t;
                            if !q_prod.push(te) {
                                anyhow::bail!("event queue closed early");
                            }
                        }
                    }
                    Ok((t_last, dl.bytes_received()))
                };
                // Always close the queue — also on error — or the worker
                // would block forever on pop().
                let result = run();
                q_prod.close();
                result
            });

            // ---- worker: assemble + reconstruct + infer
            let mut timeline = Timeline::new();
            timeline.push(0.0, 0, EventKind::StageTransferStart);
            let mut asm: Option<Assembler> = None;
            let mut results: Vec<StageResult> = Vec::new();
            let mut pending_stage: Option<(usize, f64)> = None;

            // If the worker errors, close the queue so the download
            // thread cannot block pushing into a full queue.
            let worker_result = (|| -> Result<()> {
            loop {
                // Drain everything available; keep only the newest
                // completed stage if the policy allows skipping.
                let next = if pending_stage.is_some() {
                    queue.try_pop()
                } else {
                    queue.pop()
                };
                match next {
                    Some(TimedEvent { t, event }) => match event {
                        ParserEvent::Manifest(m) => {
                            asm = Some(Assembler::new(*m));
                        }
                        ParserEvent::Fragment {
                            stage,
                            tensor,
                            payload,
                        } => {
                            let asm = asm.as_mut().expect("manifest precedes fragments");
                            if let Some(done) = asm.absorb(stage, tensor, &payload)? {
                                timeline.push(t, done, EventKind::StageTransferDone);
                                if done + 1 < asm.manifest().schedule.stages() {
                                    timeline.push(t, done + 1, EventKind::StageTransferStart);
                                }
                                match policy {
                                    InferencePolicy::LatestOnly => {
                                        pending_stage = Some((done, t)); // overwrite older
                                    }
                                    _ => {
                                        if should_infer(policy, done, asm) {
                                            let r = reconstruct_and_infer(
                                                asm,
                                                session,
                                                images,
                                                n,
                                                start,
                                                &mut timeline,
                                                t,
                                            )?;
                                            results.push(r);
                                        }
                                    }
                                }
                            }
                        }
                    },
                    None => {
                        // Queue idle (or closed): run a pending
                        // (possibly skipped-to) stage, else finish.
                        if let Some((_stage, t)) = pending_stage.take() {
                            let asm_ref = asm.as_mut().expect("manifest precedes fragments");
                            let r = reconstruct_and_infer(
                                asm_ref,
                                session,
                                images,
                                n,
                                start,
                                &mut timeline,
                                t,
                            )?;
                            results.push(r);
                            continue;
                        }
                        // pending was None, so this None came from a
                        // blocking pop() on a closed + drained queue.
                        break;
                    }
                }
            }
            Ok(())
            })();
            if worker_result.is_err() {
                queue.close();
            }

            let dl_result = downloader.join().expect("download thread");
            worker_result?; // a worker error is the root cause — report it
            let (t_transfer_complete, bytes) = dl_result?;
            let t_total = results
                .last()
                .map(|r| r.t_output_ready)
                .unwrap_or(t_transfer_complete)
                .max(t_transfer_complete);
            Ok(SessionOutcome {
                results,
                t_transfer_complete,
                t_total,
                bytes,
                timeline,
            })
        })
    }
}

fn should_infer(policy: InferencePolicy, done_stage: usize, asm: &Assembler) -> bool {
    match policy {
        InferencePolicy::EveryStage => true,
        InferencePolicy::LatestOnly => true,
        InferencePolicy::FinalOnly => done_stage + 1 == asm.manifest().schedule.stages(),
    }
}

fn reconstruct_and_infer(
    asm: &mut Assembler,
    session: &ModelSession,
    images: &[f32],
    n: usize,
    start: Instant,
    timeline: &mut Timeline,
    t_transfer_done: f64,
) -> Result<StageResult> {
    let stage = asm.stages_complete() - 1;
    let cum_bits = asm.cum_bits();
    let t0 = start.elapsed().as_secs_f64();
    timeline.push(t0, stage, EventKind::ReconstructStart);
    asm.reconstruct()?;
    let t1 = start.elapsed().as_secs_f64();
    timeline.push(t1, stage, EventKind::ReconstructDone);
    timeline.push(t1, stage, EventKind::InferStart);
    let output = session.infer(images, n, asm.flat())?;
    let t2 = start.elapsed().as_secs_f64();
    timeline.push(t2, stage, EventKind::InferDone);
    timeline.push(t2, stage, EventKind::OutputReady);
    Ok(StageResult {
        stage,
        cum_bits,
        output,
        t_transfer_done,
        t_output_ready: t2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;
    use crate::runtime::Engine;
    use crate::server::service::ServerConfig;
    use crate::server::{Repository, Server};
    use std::sync::Arc;

    fn setup() -> Option<(Server, ModelSession, Vec<f32>)> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let repo = Arc::new(Repository::open_default().unwrap());
        let server = Server::start("127.0.0.1:0", repo, ServerConfig::default()).unwrap();
        let engine = Engine::global().unwrap();
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let session = ModelSession::load_batches(&engine, m, &[1]).unwrap();
        let images = vec![0.4f32; m.input_numel()];
        Some((server, session, images))
    }

    #[test]
    fn concurrent_yields_eight_stage_results() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let opts = ProgressiveOptions::concurrent("mlp");
        let out = client
            .fetch_and_infer(&opts, &session, &images, 1)
            .unwrap();
        assert_eq!(out.results.len(), 8);
        for (i, r) in out.results.iter().enumerate() {
            assert_eq!(r.stage, i);
            assert_eq!(r.cum_bits, 2 * (i as u32 + 1));
            assert_eq!(r.output.n(), 1);
        }
        assert!(out.t_total >= out.results[0].t_output_ready);
    }

    #[test]
    fn serial_matches_stage_count() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let opts = ProgressiveOptions::serial("mlp");
        let out = client
            .fetch_and_infer(&opts, &session, &images, 1)
            .unwrap();
        assert_eq!(out.results.len(), 8);
        // stage outputs are ordered in time
        for w in out.results.windows(2) {
            assert!(w[0].t_output_ready <= w[1].t_output_ready);
        }
    }

    #[test]
    fn final_only_policy_runs_once() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let mut opts = ProgressiveOptions::concurrent("mlp");
        opts.policy = InferencePolicy::FinalOnly;
        let out = client
            .fetch_and_infer(&opts, &session, &images, 1)
            .unwrap();
        assert_eq!(out.results.len(), 1);
        assert_eq!(out.results[0].cum_bits, 16);
    }

    #[test]
    fn final_stage_matches_direct_inference() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let out = client
            .fetch_and_infer(&ProgressiveOptions::concurrent("mlp"), &session, &images, 1)
            .unwrap();
        // Direct inference with fully dequantized weights == last stage.
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let flat = m.load_weights().unwrap();
        use crate::quant::{quantize, DequantParams, QuantParams, K};
        let mut deq = vec![0f32; flat.len()];
        for t in &m.tensors {
            let seg = &flat[t.offset..t.offset + t.numel];
            let qp = QuantParams::from_data(seg, K);
            let q = quantize::quantize(seg, &qp);
            crate::quant::dequantize_into(
                &q,
                DequantParams::new(&qp, K),
                &mut deq[t.offset..t.offset + t.numel],
            );
        }
        let direct = session.infer(&images, 1, &deq).unwrap();
        let last = &out.results.last().unwrap().output;
        for (a, b) in direct.data.iter().zip(&last.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
