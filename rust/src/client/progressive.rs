//! The blocking progressive-fetch convenience layer (Fig 1, right half;
//! Fig 4 timelines), now a thin adapter over
//! [`session::ProgressiveSession`](super::session::ProgressiveSession).
//!
//! [`ProgressiveClient::fetch_and_infer`] keeps the original
//! run-to-completion calling convention — build the session, drain its
//! event stream, hand back a [`SessionOutcome`] — while all transfer,
//! resume and inference mechanics live in the session driver. New code
//! should use the session builder directly: it exposes the per-stage
//! events and the hot-swapping
//! [`ApproxModel`](crate::runtime::ApproxModel) this wrapper discards.

#![forbid(unsafe_code)]

use crate::util::sync::Arc;

use anyhow::Result;

use super::session::ProgressiveSession;
use crate::runtime::ModelSession;
use crate::server::proto::FetchRequest;

pub use super::session::{ExecMode, InferencePolicy, SessionOutcome, StageResult};

/// Options for a progressive fetch.
#[derive(Debug, Clone)]
pub struct ProgressiveOptions {
    pub mode: ExecMode,
    pub policy: InferencePolicy,
    pub request: FetchRequest,
    /// On a dropped connection, reconnect at the last complete stage
    /// boundary up to this many times (0 = fail fast).
    pub resume_retries: usize,
}

impl ProgressiveOptions {
    pub fn concurrent(model: &str) -> Self {
        Self {
            mode: ExecMode::Concurrent,
            policy: InferencePolicy::EveryStage,
            request: FetchRequest::new(model),
            resume_retries: 2,
        }
    }

    pub fn serial(model: &str) -> Self {
        Self {
            mode: ExecMode::Serial,
            policy: InferencePolicy::EveryStage,
            request: FetchRequest::new(model),
            resume_retries: 2,
        }
    }
}

/// Blocking progressive model client.
#[deprecated(
    since = "0.3.0",
    note = "use client::session::ProgressiveSession — builder, typed event \
            stream, and a hot-swappable ApproxModel handle"
)]
pub struct ProgressiveClient {
    addr: std::net::SocketAddr,
}

#[allow(deprecated)]
impl ProgressiveClient {
    pub fn new(addr: std::net::SocketAddr) -> Self {
        Self { addr }
    }

    /// Fetch `opts.request.model` and run inference on `images` (n
    /// samples) at every stage dictated by the policy, blocking until
    /// the transfer finishes.
    pub fn fetch_and_infer(
        &self,
        opts: &ProgressiveOptions,
        session: &ModelSession,
        images: &[f32],
        n: usize,
    ) -> Result<SessionOutcome> {
        let model = opts.request.model.clone();
        let report = ProgressiveSession::builder(&model)
            .addr(self.addr)
            .request(opts.request.clone())
            .mode(opts.mode)
            .policy(opts.policy)
            .resume_retries(opts.resume_retries)
            .runtime(&model, Arc::new(session.clone()))
            .workload(images.to_vec(), n)
            .start()?
            .run()?;
        Ok(report.into_outcome())
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::models::Registry;
    use crate::runtime::Engine;
    use crate::server::service::ServerConfig;
    use crate::server::{Repository, Server};
    use crate::util::sync::Arc;

    fn setup() -> Option<(Server, ModelSession, Vec<f32>)> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let repo = Arc::new(Repository::open_default().unwrap());
        let server = Server::start("127.0.0.1:0", repo, ServerConfig::default()).unwrap();
        let engine = Engine::global().unwrap();
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let session = ModelSession::load_batches(&engine, m, &[1]).unwrap();
        let images = vec![0.4f32; m.input_numel()];
        Some((server, session, images))
    }

    #[test]
    fn concurrent_yields_eight_stage_results() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let opts = ProgressiveOptions::concurrent("mlp");
        let out = client
            .fetch_and_infer(&opts, &session, &images, 1)
            .unwrap();
        assert_eq!(out.results.len(), 8);
        for (i, r) in out.results.iter().enumerate() {
            assert_eq!(r.stage, i);
            assert_eq!(r.cum_bits, 2 * (i as u32 + 1));
            assert_eq!(r.output.n(), 1);
        }
        assert!(out.t_total >= out.results[0].t_output_ready);
    }

    #[test]
    fn serial_matches_stage_count() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let opts = ProgressiveOptions::serial("mlp");
        let out = client
            .fetch_and_infer(&opts, &session, &images, 1)
            .unwrap();
        assert_eq!(out.results.len(), 8);
        // stage outputs are ordered in time
        for w in out.results.windows(2) {
            assert!(w[0].t_output_ready <= w[1].t_output_ready);
        }
    }

    #[test]
    fn final_only_policy_runs_once() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let mut opts = ProgressiveOptions::concurrent("mlp");
        opts.policy = InferencePolicy::FinalOnly;
        let out = client
            .fetch_and_infer(&opts, &session, &images, 1)
            .unwrap();
        assert_eq!(out.results.len(), 1);
        assert_eq!(out.results[0].cum_bits, 16);
    }

    #[test]
    fn final_stage_matches_direct_inference() {
        let Some((server, session, images)) = setup() else { return };
        let client = ProgressiveClient::new(server.addr());
        let out = client
            .fetch_and_infer(&ProgressiveOptions::concurrent("mlp"), &session, &images, 1)
            .unwrap();
        // Direct inference with fully dequantized weights == last stage.
        let reg = Registry::open_default().unwrap();
        let m = reg.get("mlp").unwrap();
        let flat = m.load_weights().unwrap();
        use crate::quant::{quantize, DequantParams, QuantParams, K};
        let mut deq = vec![0f32; flat.len()];
        for t in &m.tensors {
            let seg = &flat[t.offset..t.offset + t.numel];
            let qp = QuantParams::from_data(seg, K);
            let q = quantize::quantize(seg, &qp);
            crate::quant::dequantize_into(
                &q,
                DequantParams::new(&qp, K),
                &mut deq[t.offset..t.offset + t.numel],
            );
        }
        let direct = session.infer(&images, 1, &deq).unwrap();
        let last = &out.results.last().unwrap().output;
        for (a, b) in direct.data.iter().zip(&last.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
