//! Persistent download cache with resume.
//!
//! Mirrors what a browser cache / app storage does in the paper's
//! scenarios (Fig 2): a partially transmitted `.pnet` is kept on disk and
//! resumed with the server's `offset` support, so an interrupted download
//! costs only the missing bytes. Completed containers are reused without
//! touching the network.

#![forbid(unsafe_code)]

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::format::{validated_prefix, PnetReader};
use crate::server::proto::FetchRequest;
use crate::server::service::open_fetch;

/// On-disk cache of `.pnet` containers, keyed by model + schedule.
pub struct ModelCache {
    dir: PathBuf,
}

/// Outcome of a cached fetch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FetchOutcome {
    /// served entirely from cache
    CacheHit,
    /// resumed a partial file (bytes downloaded now)
    Resumed { fetched: u64 },
    /// full download (bytes downloaded)
    Downloaded { fetched: u64 },
}

impl ModelCache {
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        Ok(Self {
            dir: dir.to_path_buf(),
        })
    }

    fn key_path(&self, req: &FetchRequest) -> PathBuf {
        let sched = req
            .schedule
            .as_ref()
            .map(|s| {
                s.widths()
                    .iter()
                    .map(|w| w.to_string())
                    .collect::<Vec<_>>()
                    .join("-")
            })
            .unwrap_or_else(|| "default".into());
        self.dir.join(format!("{}.{sched}.pnet", req.model))
    }

    fn part_path(&self, req: &FetchRequest) -> PathBuf {
        self.key_path(req).with_extension("pnet.part")
    }

    /// Complete cached container for `req`, if present and still valid.
    /// Corrupt entries are evicted on read.
    pub fn load_complete(&self, req: &FetchRequest) -> Option<Vec<u8>> {
        let path = self.key_path(req);
        let bytes = std::fs::read(&path).ok()?;
        if PnetReader::from_bytes(&bytes).is_ok() {
            return Some(bytes);
        }
        crate::log_warn!("cache entry {} corrupt; evicting", path.display());
        let _ = std::fs::remove_file(&path);
        None
    }

    /// Raw bytes of a previously persisted partial download, if any.
    pub fn load_partial(&self, req: &FetchRequest) -> Option<Vec<u8>> {
        std::fs::read(self.part_path(req))
            .ok()
            .filter(|b| !b.is_empty())
    }

    /// Promote a complete, validated container into the cache and drop
    /// the partial.
    pub fn store_complete(&self, req: &FetchRequest, bytes: &[u8]) -> Result<()> {
        PnetReader::from_bytes(bytes).context("refusing to cache an invalid container")?;
        std::fs::write(self.key_path(req), bytes)?;
        let _ = std::fs::remove_file(self.part_path(req));
        Ok(())
    }

    /// Fetch a container, using cache + resume. Returns the complete
    /// container bytes and how they were obtained.
    ///
    /// A damaged partial — truncated mid-frame, stale CRC, or outright
    /// garbage — never surfaces as an error: it is first truncated to its
    /// last CRC-valid stage boundary ([`validated_prefix`]), and if the
    /// resumed download still fails to validate the fetch restarts once
    /// from byte zero.
    pub fn fetch(
        &self,
        addr: &std::net::SocketAddr,
        req: &FetchRequest,
    ) -> Result<(Vec<u8>, FetchOutcome)> {
        if let Some(bytes) = self.load_complete(req) {
            return Ok((bytes, FetchOutcome::CacheHit));
        }
        let part_path = self.part_path(req);
        let mut existing = if part_path.exists() {
            std::fs::read(&part_path)?
        } else {
            Vec::new()
        };
        if !existing.is_empty() {
            let (valid, stages) = validated_prefix(&existing);
            if valid < existing.len() {
                crate::log_warn!(
                    "partial {} invalid past byte {valid} ({stages} complete stages); truncating",
                    part_path.display()
                );
                existing.truncate(valid);
            }
        }
        let resumed = !existing.is_empty();
        match self.attempt(addr, req, existing) {
            Ok(ok) => Ok(ok),
            Err(e) if resumed => {
                crate::log_warn!("resume failed ({e:#}); retrying with a clean fetch");
                let _ = std::fs::remove_file(&part_path);
                self.attempt(addr, req, Vec::new())
            }
            Err(e) => Err(e),
        }
    }

    /// One download attempt starting from `existing` (possibly empty)
    /// already-validated bytes.
    fn attempt(
        &self,
        addr: &std::net::SocketAddr,
        req: &FetchRequest,
        mut existing: Vec<u8>,
    ) -> Result<(Vec<u8>, FetchOutcome)> {
        let final_path = self.key_path(req);
        let part_path = self.part_path(req);

        let attempt_req = req.clone().with_offset(existing.len() as u64);
        let (mut stream, mut resp) = match open_fetch(addr, &attempt_req) {
            Ok(ok) => ok,
            Err(_) if !existing.is_empty() => {
                // stale partial (e.g. server re-encoded); restart clean
                existing.clear();
                open_fetch(addr, req)?
            }
            Err(e) => return Err(e),
        };
        if (existing.len() as u64) > resp.total {
            // partial longer than the container: stale — restart
            existing.clear();
            drop(stream);
            let (s2, r2) = open_fetch(addr, req)?;
            stream = s2;
            resp = r2;
        }
        let resumed_from = existing.len() as u64;
        let mut fetched = 0u64;
        let mut buf = [0u8; 16 * 1024];
        loop {
            let n = stream.read(&mut buf)?;
            if n == 0 {
                break;
            }
            existing.extend_from_slice(&buf[..n]);
            fetched += n as u64;
            // checkpoint the partial periodically
            if fetched % (256 * 1024) < buf.len() as u64 {
                self.write_part(&part_path, &existing)?;
            }
        }
        // the server advertises exactly how many bytes follow a resume
        anyhow::ensure!(
            fetched == resp.remaining && existing.len() as u64 == resp.total,
            "download incomplete: got {fetched} of {} advertised ({} / {} total)",
            resp.remaining,
            existing.len(),
            resp.total
        );
        // validate + promote to final
        PnetReader::from_bytes(&existing).context("downloaded container invalid")?;
        std::fs::write(&final_path, &existing)?;
        let _ = std::fs::remove_file(&part_path);
        let outcome = if resumed_from > 0 {
            FetchOutcome::Resumed { fetched }
        } else {
            FetchOutcome::Downloaded { fetched }
        };
        Ok((existing, outcome))
    }

    fn write_part(&self, path: &Path, data: &[u8]) -> Result<()> {
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(data)?;
        f.flush()?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Persist a partial download (any canonical byte prefix of the
    /// container). `client::session::ProgressiveSession` calls this at
    /// every stage boundary so an interrupted session resumes from the
    /// last cached complete stage instead of stage 0; tests use it to
    /// plant interrupted downloads.
    pub fn store_partial(&self, req: &FetchRequest, data: &[u8]) -> Result<()> {
        self.write_part(&self.part_path(req), data)
    }

    pub fn evict(&self, req: &FetchRequest) {
        let _ = std::fs::remove_file(self.key_path(req));
        let _ = std::fs::remove_file(self.part_path(req));
    }

    pub fn has(&self, req: &FetchRequest) -> bool {
        self.key_path(req).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::service::ServerConfig;
    use crate::server::{Repository, Server};
    use crate::util::sync::Arc;

    fn setup() -> Option<(Server, Arc<Repository>, ModelCache)> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let repo = Arc::new(Repository::open_default().unwrap());
        let server = Server::start("127.0.0.1:0", repo.clone(), ServerConfig::default()).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "prognet-cache-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = ModelCache::open(&dir).unwrap();
        Some((server, repo, cache))
    }

    #[test]
    fn download_then_hit() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        let (bytes, outcome) = cache.fetch(&server.addr(), &req).unwrap();
        assert!(matches!(outcome, FetchOutcome::Downloaded { .. }));
        let expect = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        assert_eq!(&bytes[..], &expect[..]);

        // second fetch: no network (kill the server to prove it)
        drop(server);
        let (bytes2, outcome2) = cache.fetch(&"127.0.0.1:1".parse().unwrap(), &req).unwrap();
        assert_eq!(outcome2, FetchOutcome::CacheHit);
        assert_eq!(bytes2, bytes);
    }

    #[test]
    fn resume_from_partial() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        let full = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        // plant a half-downloaded partial; resume restarts from the last
        // complete stage boundary within it
        let half = full.len() / 2;
        let (boundary, stages) = crate::format::validated_prefix(&full[..half]);
        assert!(boundary > 0 && stages > 0, "fixture too small for resume");
        cache.store_partial(&req, &full[..half]).unwrap();
        let (bytes, outcome) = cache.fetch(&server.addr(), &req).unwrap();
        match outcome {
            FetchOutcome::Resumed { fetched } => {
                assert_eq!(fetched as usize, full.len() - boundary);
            }
            o => panic!("expected resume, got {o:?}"),
        }
        assert_eq!(&bytes[..], &full[..]);
    }

    #[test]
    fn truncated_mid_frame_partial_falls_back_cleanly() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        let full = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        // cut inside the very first fragment: no complete stage survives,
        // so the fetch must restart from byte zero rather than error
        let (preamble_only, stages) = crate::format::validated_prefix(&full[..full.len() / 8]);
        cache
            .store_partial(&req, &full[..full.len() / 8])
            .unwrap();
        let (bytes, outcome) = cache.fetch(&server.addr(), &req).unwrap();
        if stages == 0 {
            assert!(
                matches!(outcome, FetchOutcome::Downloaded { .. })
                    || preamble_only > 0 && matches!(outcome, FetchOutcome::Resumed { .. }),
                "got {outcome:?}"
            );
        }
        assert_eq!(&bytes[..], &full[..]);
    }

    #[test]
    fn stale_crc_partial_falls_back_cleanly() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        let full = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        // corrupt a byte in the middle of a planted half-container: the
        // CRC mismatch must truncate the resume point, never surface as
        // "downloaded container invalid"
        let half = full.len() / 2;
        let mut bad = full[..half].to_vec();
        bad[half / 2] ^= 0xFF;
        cache.store_partial(&req, &bad).unwrap();
        let (bytes, _outcome) = cache.fetch(&server.addr(), &req).unwrap();
        assert_eq!(&bytes[..], &full[..]);
        // and the promoted entry is clean
        assert_eq!(&cache.load_complete(&req).unwrap()[..], &full[..]);
    }

    #[test]
    fn garbage_partial_falls_back_cleanly() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        let full = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        // unparseable preamble: sanitizer drops the whole partial
        cache.store_partial(&req, &[0xAB; 512]).unwrap();
        let (bytes, outcome) = cache.fetch(&server.addr(), &req).unwrap();
        assert!(matches!(outcome, FetchOutcome::Downloaded { .. }));
        assert_eq!(&bytes[..], &full[..]);
    }

    #[test]
    fn corrupt_cache_entry_refetched() {
        let Some((server, _repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        cache.fetch(&server.addr(), &req).unwrap();
        // corrupt the cached file
        let path = cache.key_path(&req);
        let mut data = std::fs::read(&path).unwrap();
        let n = data.len();
        data[n - 5] ^= 0xFF;
        std::fs::write(&path, &data).unwrap();
        let (bytes, outcome) = cache.fetch(&server.addr(), &req).unwrap();
        assert!(matches!(outcome, FetchOutcome::Downloaded { .. }));
        assert!(PnetReader::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn stale_oversized_partial_restarts() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        let full = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        // partial longer than the real container (server re-encoded)
        let mut bogus = full.to_vec();
        bogus.extend_from_slice(&[0u8; 1024]);
        cache.store_partial(&req, &bogus).unwrap();
        let (bytes, _) = cache.fetch(&server.addr(), &req).unwrap();
        assert_eq!(&bytes[..], &full[..]);
    }

    #[test]
    fn partial_and_complete_round_trip() {
        let Some((server, repo, cache)) = setup() else { return };
        let req = FetchRequest::new("mlp");
        assert!(cache.load_partial(&req).is_none());
        assert!(cache.load_complete(&req).is_none());
        let full = repo
            .container("mlp", &crate::quant::Schedule::paper_default())
            .unwrap();
        cache.store_partial(&req, &full[..full.len() / 3]).unwrap();
        assert_eq!(
            cache.load_partial(&req).unwrap().len(),
            full.len() / 3
        );
        // a truncated container is rejected for promotion …
        assert!(cache.store_complete(&req, &full[..full.len() / 3]).is_err());
        // … the real thing promotes and clears the partial
        cache.store_complete(&req, &full).unwrap();
        assert!(cache.load_partial(&req).is_none());
        assert_eq!(&cache.load_complete(&req).unwrap()[..], &full[..]);
        drop(server);
    }

    #[test]
    fn distinct_schedules_cached_separately() {
        let Some((server, _repo, cache)) = setup() else { return };
        let a = FetchRequest::new("mlp");
        let b = FetchRequest::new("mlp")
            .with_schedule(crate::quant::Schedule::new(vec![8, 8], 16).unwrap());
        cache.fetch(&server.addr(), &a).unwrap();
        assert!(cache.has(&a));
        assert!(!cache.has(&b));
        let (bytes_b, _) = cache.fetch(&server.addr(), &b).unwrap();
        let r = PnetReader::from_bytes(&bytes_b).unwrap();
        assert_eq!(r.manifest.schedule.stages(), 2);
    }
}
