//! Incremental model assembly: per-tensor Eq. 4 accumulators + Eq. 5
//! dequantization into a reusable flat weight buffer.

use anyhow::{bail, Result};

use crate::format::header::PnetManifest;
use crate::quant::{dequantize_into, Accumulator, DequantParams};

/// Assembles a progressive model from fragments, tensor by tensor.
pub struct Assembler {
    manifest: PnetManifest,
    accs: Vec<Accumulator>,
    /// number of tensors that completed each stage
    stage_counts: Vec<usize>,
    /// highest stage for which *all* tensors have arrived, +1 (0 = none)
    stages_complete: usize,
    /// reusable dequantized flat weights
    flat: Vec<f32>,
    /// stage reflected in `flat` (+1), 0 = never dequantized
    flat_stage: usize,
}

impl Assembler {
    pub fn new(manifest: PnetManifest) -> Self {
        let accs = manifest
            .tensors
            .iter()
            .map(|t| Accumulator::new(t.numel, manifest.schedule.clone()))
            .collect();
        let stage_counts = vec![0; manifest.schedule.stages()];
        let flat = vec![0f32; manifest.param_count()];
        Self {
            manifest,
            accs,
            stage_counts,
            stages_complete: 0,
            flat,
            flat_stage: 0,
        }
    }

    pub fn manifest(&self) -> &PnetManifest {
        &self.manifest
    }

    /// Absorb one fragment; returns `Some(stage)` when this fragment
    /// completed that stage across all tensors.
    pub fn absorb(&mut self, stage: usize, tensor: usize, payload: &[u8]) -> Result<Option<usize>> {
        if tensor >= self.accs.len() {
            bail!("tensor index {tensor} out of range");
        }
        if stage >= self.manifest.schedule.stages() {
            bail!("stage {stage} out of range");
        }
        let acc = &mut self.accs[tensor];
        if stage < acc.stages_received() {
            // duplicate fragment — a stage-boundary resume re-delivers the
            // partially received stage; the codes are already absorbed
            return Ok(None);
        }
        if acc.stages_received() != stage {
            bail!(
                "tensor {tensor}: expected stage {}, got {stage}",
                acc.stages_received()
            );
        }
        acc.absorb(payload)?;
        self.stage_counts[stage] += 1;
        if self.stage_counts[stage] == self.accs.len() && self.stages_complete == stage {
            self.stages_complete = stage + 1;
            return Ok(Some(stage));
        }
        Ok(None)
    }

    /// Number of fully received stages.
    pub fn stages_complete(&self) -> usize {
        self.stages_complete
    }

    pub fn is_complete(&self) -> bool {
        self.stages_complete == self.manifest.schedule.stages()
    }

    /// Cumulative bits of the last complete stage (0 if none).
    pub fn cum_bits(&self) -> u32 {
        if self.stages_complete == 0 {
            0
        } else {
            self.manifest.schedule.cum_bits(self.stages_complete - 1)
        }
    }

    /// Dequantize the current state into the internal flat buffer and
    /// return it (Eq. 5 with the midpoint revision for missing bits).
    ///
    /// This is the per-stage reconstruct hot path. The buffer is reused;
    /// no allocation happens after construction.
    pub fn reconstruct(&mut self) -> Result<&[f32]> {
        if self.stages_complete == 0 {
            bail!("no complete stage to reconstruct");
        }
        let cum = self.cum_bits();
        for (t, acc) in self.manifest.tensors.iter().zip(&self.accs) {
            let qp = t.quant_params(self.manifest.k);
            let dp = DequantParams::new(&qp, cum);
            dequantize_into(
                acc.codes(),
                dp,
                &mut self.flat[t.offset..t.offset + t.numel],
            );
        }
        self.flat_stage = self.stages_complete;
        Ok(&self.flat)
    }

    /// The current flat code vector concatenated across tensors (for the
    /// fused `qfwd` path — dequant runs inside the executable instead).
    pub fn codes_flat(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.manifest.param_count()];
        for (t, acc) in self.manifest.tensors.iter().zip(&self.accs) {
            out[t.offset..t.offset + t.numel].copy_from_slice(acc.codes());
        }
        out
    }

    /// Last reconstructed weights without re-running dequant.
    pub fn flat(&self) -> &[f32] {
        &self.flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::header::manifest_from_weights;
    use crate::format::PnetWriter;
    use crate::quant::Schedule;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (PnetWriter, Vec<f32>) {
        let mut r = Rng::new(seed);
        let flat: Vec<f32> = (0..800).map(|_| r.normal() as f32).collect();
        let m = manifest_from_weights(
            "toy",
            "classify",
            &[
                ("w1".to_string(), vec![20, 30]),
                ("b1".to_string(), vec![30]),
                ("w2".to_string(), vec![170]),
            ],
            &flat,
            Schedule::paper_default(),
        )
        .unwrap();
        (PnetWriter::encode(m, &flat).unwrap(), flat)
    }

    #[test]
    fn stage_completion_tracking() {
        let (w, _) = setup(1);
        let mut asm = Assembler::new(w.manifest().clone());
        assert_eq!(asm.stages_complete(), 0);
        // stage 0, tensors 0..2
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), None);
        assert_eq!(asm.absorb(0, 1, w.fragment(0, 1)).unwrap(), None);
        assert_eq!(asm.absorb(0, 2, w.fragment(0, 2)).unwrap(), Some(0));
        assert_eq!(asm.stages_complete(), 1);
        assert_eq!(asm.cum_bits(), 2);
    }

    #[test]
    fn reconstruction_error_shrinks_with_stages() {
        let (w, orig) = setup(2);
        let mut asm = Assembler::new(w.manifest().clone());
        let mut prev = f32::INFINITY;
        for s in 0..8 {
            for t in 0..3 {
                asm.absorb(s, t, w.fragment(s, t)).unwrap();
            }
            let flat = asm.reconstruct().unwrap();
            let err = flat
                .iter()
                .zip(&orig)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(err <= prev + 1e-6);
            prev = err;
        }
        assert!(asm.is_complete());
        // full 16-bit reconstruction: tight error
        let max_range = w
            .manifest()
            .tensors
            .iter()
            .map(|t| t.max - t.min)
            .fold(0f32, f32::max);
        assert!(prev <= max_range / 65536.0 + 1e-6);
    }

    #[test]
    fn out_of_order_fragment_rejected() {
        let (w, _) = setup(3);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.absorb(1, 0, w.fragment(1, 0)).is_err());
    }

    #[test]
    fn duplicate_fragment_skipped_not_double_counted() {
        let (w, _) = setup(6);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        let codes_before = asm.codes_flat();
        // a stage-boundary resume re-delivers stage 0: must be a no-op
        for t in 0..3 {
            assert_eq!(asm.absorb(0, t, w.fragment(0, t)).unwrap(), None);
        }
        assert_eq!(asm.stages_complete(), 1);
        assert_eq!(asm.codes_flat(), codes_before);
        // and the next stage still completes normally
        for t in 0..3 {
            asm.absorb(1, t, w.fragment(1, t)).unwrap();
        }
        assert_eq!(asm.stages_complete(), 2);
    }

    #[test]
    fn reconstruct_before_any_stage_is_error() {
        let (w, _) = setup(4);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.reconstruct().is_err());
    }

    #[test]
    fn codes_flat_matches_accumulators() {
        let (w, _) = setup(5);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        let codes = asm.codes_flat();
        assert_eq!(codes.len(), 800);
        // stage 0 = top 2 bits only
        assert!(codes.iter().all(|&c| c & 0x3FFF == 0));
    }
}
