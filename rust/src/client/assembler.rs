//! Incremental model assembly: Eq. 4 bit-concatenation into one flat
//! code vector plus Eq. 5 dequantization into a reusable flat weight
//! buffer, with an incremental stage-delta path.
//!
//! # Incremental dequant invariant
//!
//! After [`Assembler::reconstruct`] returns, `flat[i]` equals exactly
//! `(q[i] + 2^{k-c-1}) * scale + min` for the current cumulative bits
//! `c` — the same expression [`dequantize_into`] evaluates — regardless
//! of whether the floats were rewritten just now (lazy mode) or tensor
//! by tensor as each plane landed (eager mode,
//! [`Assembler::set_eager_dequant`]). Incremental updates are therefore
//! **bit-exact** with a full re-dequant at every `cum_bits` level; the
//! property test in `tests/runtime_fastpath.rs` asserts equality of the
//! raw f32 bits. In eager mode the stage-boundary `reconstruct` is pure
//! bookkeeping (every tensor is already current), so the
//! `StageComplete → ModelReady` critical path the fleet SLO measures no
//! longer contains an `O(param_count)` dequant pass — Eq. 5 runs while
//! the next bytes are still in flight.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::format::header::PnetManifest;
use crate::quant::{bitplane, dequantize_into, DequantParams};

/// `flat_cum` sentinel: the tensor's floats reflect no valid bit-width.
const STALE: u32 = u32::MAX;

/// Assembles a progressive model from fragments, tensor by tensor.
pub struct Assembler {
    manifest: PnetManifest,
    /// flat k-bit code vector, all tensors concatenated (Eq. 4 state) —
    /// borrowed out via [`Assembler::codes_flat`] without copying
    q: Vec<u32>,
    /// stages absorbed per tensor
    recv: Vec<usize>,
    /// number of tensors that completed each stage
    stage_counts: Vec<usize>,
    /// highest stage for which *all* tensors have arrived, +1 (0 = none)
    stages_complete: usize,
    /// reusable dequantized flat weights
    flat: Vec<f32>,
    /// cumulative bits reflected in `flat`, per tensor ([`STALE`] = none)
    flat_cum: Vec<u32>,
    /// monotone counter identifying the contents of `q` (bumps on every
    /// absorbed fragment) — the backend's qfwd weight-cache key
    version: u64,
    /// fold Eq. 5 into absorb (per-tensor, as planes land)
    eager: bool,
    /// `LayerMajor` boundaries: tensor index where each layer starts,
    /// plus one final entry = tensor count; empty when unannotated
    layer_bounds: Vec<usize>,
    /// per-tensor layer index (empty when unannotated)
    tensor_layer: Vec<usize>,
    /// highest stage announced per layer (+1; 0 = none announced)
    layer_done: Vec<usize>,
    /// `(layer, stage)` completions not yet drained, in completion order
    pending_layers: Vec<(usize, usize)>,
}

impl Assembler {
    pub fn new(manifest: PnetManifest) -> Self {
        let tensors = manifest.tensors.len();
        let params = manifest.param_count();
        let stage_counts = vec![0; manifest.schedule.stages()];
        let (layer_bounds, tensor_layer) = match &manifest.layers {
            None => (Vec::new(), Vec::new()),
            Some(counts) => {
                let mut bounds = Vec::with_capacity(counts.len() + 1);
                let mut map = Vec::with_capacity(tensors);
                let mut at = 0;
                bounds.push(0);
                for (l, &c) in counts.iter().enumerate() {
                    at += c;
                    bounds.push(at);
                    map.extend(std::iter::repeat(l).take(c));
                }
                (bounds, map)
            }
        };
        let layers = layer_bounds.len().saturating_sub(1);
        Self {
            manifest,
            q: vec![0u32; params],
            recv: vec![0; tensors],
            stage_counts,
            stages_complete: 0,
            flat: vec![0f32; params],
            flat_cum: vec![STALE; tensors],
            version: 0,
            eager: false,
            layer_bounds,
            tensor_layer,
            layer_done: vec![0; layers],
            pending_layers: Vec::new(),
        }
    }

    pub fn manifest(&self) -> &PnetManifest {
        &self.manifest
    }

    /// Fold Eq. 5 into fragment absorption: each arriving plane updates
    /// its tensor's dequantized floats in place right after the OR-shift
    /// into the code vector, so the stage-boundary [`reconstruct`] is
    /// `O(#tensors)` bookkeeping instead of a full `param_count` dequant
    /// pass. Off by default — download-only consumers never pay Eq. 5;
    /// sessions with a bound runtime turn it on.
    ///
    /// [`reconstruct`]: Assembler::reconstruct
    pub fn set_eager_dequant(&mut self, eager: bool) {
        self.eager = eager;
    }

    /// Absorb one fragment; returns `Some(stage)` when this fragment
    /// completed that stage across all tensors.
    pub fn absorb(&mut self, stage: usize, tensor: usize, payload: &[u8]) -> Result<Option<usize>> {
        if tensor >= self.recv.len() {
            bail!("tensor index {tensor} out of range");
        }
        if stage >= self.manifest.schedule.stages() {
            bail!("stage {stage} out of range");
        }
        if stage < self.recv[tensor] {
            // duplicate fragment — a stage-boundary resume re-delivers the
            // partially received stage; the codes are already absorbed
            return Ok(None);
        }
        if self.recv[tensor] != stage {
            bail!(
                "tensor {tensor}: expected stage {}, got {stage}",
                self.recv[tensor]
            );
        }
        let t = &self.manifest.tensors[tensor];
        let sched = &self.manifest.schedule;
        let width = sched.widths()[stage];
        let expect = sched.plane_bytes(stage, t.numel);
        if payload.len() != expect {
            bail!(
                "stage {stage} plane is {} bytes, expected {expect}",
                payload.len()
            );
        }
        let cum = sched.cum_bits(stage);
        let shift = sched.k() - cum;
        // Fused unpack + shift + OR straight into the flat code vector —
        // single pass, no scratch. Stage 0 overwrites (q is all-zero).
        bitplane::unpack_or_into(
            payload,
            width,
            shift,
            stage == 0,
            &mut self.q[t.offset..t.offset + t.numel],
        );
        self.recv[tensor] = stage + 1;
        self.version += 1;
        if self.eager {
            // stage-delta dequant: rewrite only this tensor's floats, at
            // its own new bit-width, while the download keeps flowing
            let dp = DequantParams::new(&t.quant_params(self.manifest.k), cum);
            dequantize_into(
                &self.q[t.offset..t.offset + t.numel],
                dp,
                &mut self.flat[t.offset..t.offset + t.numel],
            );
            self.flat_cum[tensor] = cum;
        } else {
            self.flat_cum[tensor] = STALE;
        }
        if !self.layer_bounds.is_empty() {
            // layer completion: the layer's lowest per-tensor stage just
            // caught up (absorption is in-order per tensor, so the min
            // rises by at most one per fragment)
            let l = self.tensor_layer[tensor];
            let span = self.layer_bounds[l]..self.layer_bounds[l + 1];
            let min = self.recv[span].iter().copied().min().expect("non-empty layer");
            while self.layer_done[l] < min {
                self.pending_layers.push((l, self.layer_done[l]));
                self.layer_done[l] += 1;
            }
        }
        self.stage_counts[stage] += 1;
        if self.stage_counts[stage] == self.recv.len() && self.stages_complete == stage {
            self.stages_complete = stage + 1;
            return Ok(Some(stage));
        }
        Ok(None)
    }

    /// Number of annotated layers (0 when the manifest carries no
    /// `LayerMajor` annotation — per-layer events are then never emitted).
    pub fn layer_count(&self) -> usize {
        self.layer_done.len()
    }

    /// Stages fully received for `layer` (every tensor in the layer), as
    /// a count: `k` means stages `0..k` of this layer have landed.
    pub fn layer_stages_complete(&self, layer: usize) -> usize {
        self.layer_done[layer]
    }

    /// Flat-weight element range covered by `layer`'s tensors.
    pub fn layer_weight_range(&self, layer: usize) -> std::ops::Range<usize> {
        let first = &self.manifest.tensors[self.layer_bounds[layer]];
        let last = &self.manifest.tensors[self.layer_bounds[layer + 1] - 1];
        first.offset..last.offset + last.numel
    }

    /// Drain `(layer, stage)` completions recorded since the last drain,
    /// in completion order. A `(l, s)` entry means every tensor of layer
    /// `l` has absorbed stage `s` — under eager dequant
    /// ([`Assembler::set_eager_dequant`]) the layer's slice of
    /// [`Assembler::flat`] already reflects those bits, so the drained
    /// event is immediately actionable by a streaming executor.
    /// Duplicate fragments (resume/reconnect re-delivery) never re-emit.
    pub fn drain_layer_events(&mut self) -> Vec<(usize, usize)> {
        std::mem::take(&mut self.pending_layers)
    }

    /// Number of fully received stages.
    pub fn stages_complete(&self) -> usize {
        self.stages_complete
    }

    pub fn is_complete(&self) -> bool {
        self.stages_complete == self.manifest.schedule.stages()
    }

    /// Cumulative bits of the last complete stage (0 if none).
    pub fn cum_bits(&self) -> u32 {
        if self.stages_complete == 0 {
            0
        } else {
            self.manifest.schedule.cum_bits(self.stages_complete - 1)
        }
    }

    /// Dequantize the current state into the internal flat buffer and
    /// return it (Eq. 5 with the midpoint revision for missing bits).
    ///
    /// Only tensors whose floats are stale for the current bit-width are
    /// rewritten; with [`Assembler::set_eager_dequant`] every tensor was
    /// updated as its plane landed, and this is `O(#tensors)` bookkeeping.
    /// Either way the result is bit-exact with a full re-dequant (see the
    /// module docs). The buffer is reused; no allocation happens after
    /// construction.
    pub fn reconstruct(&mut self) -> Result<&[f32]> {
        if self.stages_complete == 0 {
            bail!("no complete stage to reconstruct");
        }
        let cum = self.cum_bits();
        for (i, t) in self.manifest.tensors.iter().enumerate() {
            if self.flat_cum[i] == cum {
                continue;
            }
            let dp = DequantParams::new(&t.quant_params(self.manifest.k), cum);
            dequantize_into(
                &self.q[t.offset..t.offset + t.numel],
                dp,
                &mut self.flat[t.offset..t.offset + t.numel],
            );
            self.flat_cum[i] = cum;
        }
        Ok(&self.flat)
    }

    /// The current flat code vector concatenated across tensors,
    /// borrowed — the fused `qfwd` path consumes it without copying
    /// (dequant runs inside the executable instead).
    pub fn codes_flat(&self) -> &[u32] {
        &self.q
    }

    /// Monotone counter identifying the exact contents of
    /// [`Assembler::codes_flat`]: bumps on every absorbed fragment. Pair
    /// with [`Assembler::cum_bits`] as the backend's qfwd weight-cache
    /// key ([`infer_quantized_versioned`]).
    ///
    /// [`infer_quantized_versioned`]: crate::runtime::ModelSession::infer_quantized_versioned
    ///
    /// Publication safety: `version` is a plain field mutated only under
    /// `&mut self`; cross-thread visibility comes from the lock that owns
    /// the assembler (e.g. `ApproxModel`'s `RwLock` cell), whose
    /// release/acquire edge publishes the bump together with the code
    /// bytes it describes. No atomic is involved, so there is no ordering
    /// to get wrong here — keep it that way.
    pub fn codes_version(&self) -> u64 {
        self.version
    }

    /// Last reconstructed weights without re-running dequant.
    pub fn flat(&self) -> &[f32] {
        &self.flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::header::manifest_from_weights;
    use crate::format::PnetWriter;
    use crate::quant::Schedule;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (PnetWriter, Vec<f32>) {
        let mut r = Rng::new(seed);
        let flat: Vec<f32> = (0..800).map(|_| r.normal() as f32).collect();
        let m = manifest_from_weights(
            "toy",
            "classify",
            &[
                ("w1".to_string(), vec![20, 30]),
                ("b1".to_string(), vec![30]),
                ("w2".to_string(), vec![170]),
            ],
            &flat,
            Schedule::paper_default(),
        )
        .unwrap();
        (PnetWriter::encode(m, &flat).unwrap(), flat)
    }

    #[test]
    fn stage_completion_tracking() {
        let (w, _) = setup(1);
        let mut asm = Assembler::new(w.manifest().clone());
        assert_eq!(asm.stages_complete(), 0);
        assert_eq!(asm.codes_version(), 0);
        // stage 0, tensors 0..2
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), None);
        assert_eq!(asm.absorb(0, 1, w.fragment(0, 1)).unwrap(), None);
        assert_eq!(asm.absorb(0, 2, w.fragment(0, 2)).unwrap(), Some(0));
        assert_eq!(asm.stages_complete(), 1);
        assert_eq!(asm.cum_bits(), 2);
        assert_eq!(asm.codes_version(), 3);
    }

    #[test]
    fn reconstruction_error_shrinks_with_stages() {
        let (w, orig) = setup(2);
        let mut asm = Assembler::new(w.manifest().clone());
        let mut prev = f32::INFINITY;
        for s in 0..8 {
            for t in 0..3 {
                asm.absorb(s, t, w.fragment(s, t)).unwrap();
            }
            let flat = asm.reconstruct().unwrap();
            let err = flat
                .iter()
                .zip(&orig)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(err <= prev + 1e-6);
            prev = err;
        }
        assert!(asm.is_complete());
        // full 16-bit reconstruction: tight error
        let max_range = w
            .manifest()
            .tensors
            .iter()
            .map(|t| t.max - t.min)
            .fold(0f32, f32::max);
        assert!(prev <= max_range / 65536.0 + 1e-6);
    }

    #[test]
    fn eager_dequant_matches_lazy_bit_for_bit() {
        let (w, _) = setup(7);
        let mut eager = Assembler::new(w.manifest().clone());
        eager.set_eager_dequant(true);
        let mut lazy = Assembler::new(w.manifest().clone());
        for s in 0..8 {
            for t in 0..3 {
                eager.absorb(s, t, w.fragment(s, t)).unwrap();
                lazy.absorb(s, t, w.fragment(s, t)).unwrap();
            }
            let a: Vec<u32> = eager.reconstruct().unwrap().iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = lazy.reconstruct().unwrap().iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "stage {s}");
        }
    }

    #[test]
    fn out_of_order_fragment_rejected() {
        let (w, _) = setup(3);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.absorb(1, 0, w.fragment(1, 0)).is_err());
    }

    #[test]
    fn duplicate_fragment_skipped_not_double_counted() {
        let (w, _) = setup(6);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        let codes_before = asm.codes_flat().to_vec();
        let version_before = asm.codes_version();
        // a stage-boundary resume re-delivers stage 0: must be a no-op
        for t in 0..3 {
            assert_eq!(asm.absorb(0, t, w.fragment(0, t)).unwrap(), None);
        }
        assert_eq!(asm.stages_complete(), 1);
        assert_eq!(asm.codes_flat(), &codes_before[..]);
        assert_eq!(asm.codes_version(), version_before);
        // and the next stage still completes normally
        for t in 0..3 {
            asm.absorb(1, t, w.fragment(1, t)).unwrap();
        }
        assert_eq!(asm.stages_complete(), 2);
        assert!(asm.codes_version() > version_before);
    }

    #[test]
    fn reconstruct_before_any_stage_is_error() {
        let (w, _) = setup(4);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.reconstruct().is_err());
    }

    #[test]
    fn codes_flat_matches_accumulators() {
        let (w, _) = setup(5);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        let codes = asm.codes_flat();
        assert_eq!(codes.len(), 800);
        // stage 0 = top 2 bits only
        assert!(codes.iter().all(|&c| c & 0x3FFF == 0));
    }

    /// 2-layer model: (w1 [20,30] + b1 [30]) then w2 [17,10], 800 params.
    fn setup_layered(seed: u64) -> PnetWriter {
        let mut r = Rng::new(seed);
        let flat: Vec<f32> = (0..800).map(|_| r.normal() as f32).collect();
        let m = manifest_from_weights(
            "toy",
            "classify",
            &[
                ("w1".to_string(), vec![20, 30]),
                ("b1".to_string(), vec![30]),
                ("w2".to_string(), vec![17, 10]),
            ],
            &flat,
            Schedule::paper_default(),
        )
        .unwrap()
        .with_inferred_layers();
        assert_eq!(m.layers, Some(vec![2, 1]));
        PnetWriter::encode(m, &flat).unwrap()
    }

    #[test]
    fn layer_events_emitted_as_layers_complete() {
        let w = setup_layered(10);
        let mut asm = Assembler::new(w.manifest().clone());
        assert_eq!(asm.layer_count(), 2);
        assert_eq!(asm.layer_weight_range(0), 0..630);
        assert_eq!(asm.layer_weight_range(1), 630..800);
        // stage-major delivery: layer 0 fires once both its tensors land,
        // layer 1 (single tensor) right after — before the stage event
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), None);
        assert!(asm.drain_layer_events().is_empty());
        assert_eq!(asm.absorb(0, 1, w.fragment(0, 1)).unwrap(), None);
        assert_eq!(asm.drain_layer_events(), vec![(0, 0)]);
        assert_eq!(asm.absorb(0, 2, w.fragment(0, 2)).unwrap(), Some(0));
        assert_eq!(asm.drain_layer_events(), vec![(1, 0)]);
        assert_eq!(asm.layer_stages_complete(0), 1);
        assert_eq!(asm.layer_stages_complete(1), 1);
        // draining is destructive: nothing left
        assert!(asm.drain_layer_events().is_empty());
        // next stage fires both layers again, in completion order
        for t in 0..3 {
            asm.absorb(1, t, w.fragment(1, t)).unwrap();
        }
        assert_eq!(asm.drain_layer_events(), vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn layer_events_tolerate_within_stage_permutation() {
        let w = setup_layered(11);
        let mut asm = Assembler::new(w.manifest().clone());
        // layer 1's tensor first: it completes before layer 0
        assert_eq!(asm.absorb(0, 2, w.fragment(0, 2)).unwrap(), None);
        assert_eq!(asm.drain_layer_events(), vec![(1, 0)]);
        asm.absorb(0, 1, w.fragment(0, 1)).unwrap();
        assert!(asm.drain_layer_events().is_empty());
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), Some(0));
        assert_eq!(asm.drain_layer_events(), vec![(0, 0)]);
    }

    #[test]
    fn duplicate_fragments_never_reemit_layer_events() {
        let w = setup_layered(12);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        assert_eq!(asm.drain_layer_events().len(), 2);
        // resume re-delivers stage 0: no events resurface
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        assert!(asm.drain_layer_events().is_empty());
    }

    #[test]
    fn unannotated_manifest_emits_no_layer_events() {
        let (w, _) = setup(13);
        assert!(w.manifest().layers.is_none());
        let mut asm = Assembler::new(w.manifest().clone());
        assert_eq!(asm.layer_count(), 0);
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        assert!(asm.drain_layer_events().is_empty());
    }

    #[test]
    fn wrong_size_plane_rejected() {
        let (w, _) = setup(8);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.absorb(0, 0, &[0u8; 3]).is_err());
        assert_eq!(asm.stages_complete(), 0);
        assert_eq!(asm.codes_version(), 0);
        // the right-size plane still lands afterwards
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), None);
    }
}
