//! Incremental model assembly: Eq. 4 bit-concatenation into one flat
//! code vector plus Eq. 5 dequantization into a reusable flat weight
//! buffer, with an incremental stage-delta path.
//!
//! # Incremental dequant invariant
//!
//! After [`Assembler::reconstruct`] returns, `flat[i]` equals exactly
//! `(q[i] + 2^{k-c-1}) * scale + min` for the current cumulative bits
//! `c` — the same expression [`dequantize_into`] evaluates — regardless
//! of whether the floats were rewritten just now (lazy mode) or tensor
//! by tensor as each plane landed (eager mode,
//! [`Assembler::set_eager_dequant`]). Incremental updates are therefore
//! **bit-exact** with a full re-dequant at every `cum_bits` level; the
//! property test in `tests/runtime_fastpath.rs` asserts equality of the
//! raw f32 bits. In eager mode the stage-boundary `reconstruct` is pure
//! bookkeeping (every tensor is already current), so the
//! `StageComplete → ModelReady` critical path the fleet SLO measures no
//! longer contains an `O(param_count)` dequant pass — Eq. 5 runs while
//! the next bytes are still in flight.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use crate::format::header::PnetManifest;
use crate::quant::{bitplane, dequantize_into, DequantParams};

/// `flat_cum` sentinel: the tensor's floats reflect no valid bit-width.
const STALE: u32 = u32::MAX;

/// Assembles a progressive model from fragments, tensor by tensor.
pub struct Assembler {
    manifest: PnetManifest,
    /// flat k-bit code vector, all tensors concatenated (Eq. 4 state) —
    /// borrowed out via [`Assembler::codes_flat`] without copying
    q: Vec<u32>,
    /// stages absorbed per tensor
    recv: Vec<usize>,
    /// number of tensors that completed each stage
    stage_counts: Vec<usize>,
    /// highest stage for which *all* tensors have arrived, +1 (0 = none)
    stages_complete: usize,
    /// reusable dequantized flat weights
    flat: Vec<f32>,
    /// cumulative bits reflected in `flat`, per tensor ([`STALE`] = none)
    flat_cum: Vec<u32>,
    /// monotone counter identifying the contents of `q` (bumps on every
    /// absorbed fragment) — the backend's qfwd weight-cache key
    version: u64,
    /// fold Eq. 5 into absorb (per-tensor, as planes land)
    eager: bool,
}

impl Assembler {
    pub fn new(manifest: PnetManifest) -> Self {
        let tensors = manifest.tensors.len();
        let params = manifest.param_count();
        let stage_counts = vec![0; manifest.schedule.stages()];
        Self {
            manifest,
            q: vec![0u32; params],
            recv: vec![0; tensors],
            stage_counts,
            stages_complete: 0,
            flat: vec![0f32; params],
            flat_cum: vec![STALE; tensors],
            version: 0,
            eager: false,
        }
    }

    pub fn manifest(&self) -> &PnetManifest {
        &self.manifest
    }

    /// Fold Eq. 5 into fragment absorption: each arriving plane updates
    /// its tensor's dequantized floats in place right after the OR-shift
    /// into the code vector, so the stage-boundary [`reconstruct`] is
    /// `O(#tensors)` bookkeeping instead of a full `param_count` dequant
    /// pass. Off by default — download-only consumers never pay Eq. 5;
    /// sessions with a bound runtime turn it on.
    ///
    /// [`reconstruct`]: Assembler::reconstruct
    pub fn set_eager_dequant(&mut self, eager: bool) {
        self.eager = eager;
    }

    /// Absorb one fragment; returns `Some(stage)` when this fragment
    /// completed that stage across all tensors.
    pub fn absorb(&mut self, stage: usize, tensor: usize, payload: &[u8]) -> Result<Option<usize>> {
        if tensor >= self.recv.len() {
            bail!("tensor index {tensor} out of range");
        }
        if stage >= self.manifest.schedule.stages() {
            bail!("stage {stage} out of range");
        }
        if stage < self.recv[tensor] {
            // duplicate fragment — a stage-boundary resume re-delivers the
            // partially received stage; the codes are already absorbed
            return Ok(None);
        }
        if self.recv[tensor] != stage {
            bail!(
                "tensor {tensor}: expected stage {}, got {stage}",
                self.recv[tensor]
            );
        }
        let t = &self.manifest.tensors[tensor];
        let sched = &self.manifest.schedule;
        let width = sched.widths()[stage];
        let expect = sched.plane_bytes(stage, t.numel);
        if payload.len() != expect {
            bail!(
                "stage {stage} plane is {} bytes, expected {expect}",
                payload.len()
            );
        }
        let cum = sched.cum_bits(stage);
        let shift = sched.k() - cum;
        // Fused unpack + shift + OR straight into the flat code vector —
        // single pass, no scratch. Stage 0 overwrites (q is all-zero).
        bitplane::unpack_or_into(
            payload,
            width,
            shift,
            stage == 0,
            &mut self.q[t.offset..t.offset + t.numel],
        );
        self.recv[tensor] = stage + 1;
        self.version += 1;
        if self.eager {
            // stage-delta dequant: rewrite only this tensor's floats, at
            // its own new bit-width, while the download keeps flowing
            let dp = DequantParams::new(&t.quant_params(self.manifest.k), cum);
            dequantize_into(
                &self.q[t.offset..t.offset + t.numel],
                dp,
                &mut self.flat[t.offset..t.offset + t.numel],
            );
            self.flat_cum[tensor] = cum;
        } else {
            self.flat_cum[tensor] = STALE;
        }
        self.stage_counts[stage] += 1;
        if self.stage_counts[stage] == self.recv.len() && self.stages_complete == stage {
            self.stages_complete = stage + 1;
            return Ok(Some(stage));
        }
        Ok(None)
    }

    /// Number of fully received stages.
    pub fn stages_complete(&self) -> usize {
        self.stages_complete
    }

    pub fn is_complete(&self) -> bool {
        self.stages_complete == self.manifest.schedule.stages()
    }

    /// Cumulative bits of the last complete stage (0 if none).
    pub fn cum_bits(&self) -> u32 {
        if self.stages_complete == 0 {
            0
        } else {
            self.manifest.schedule.cum_bits(self.stages_complete - 1)
        }
    }

    /// Dequantize the current state into the internal flat buffer and
    /// return it (Eq. 5 with the midpoint revision for missing bits).
    ///
    /// Only tensors whose floats are stale for the current bit-width are
    /// rewritten; with [`Assembler::set_eager_dequant`] every tensor was
    /// updated as its plane landed, and this is `O(#tensors)` bookkeeping.
    /// Either way the result is bit-exact with a full re-dequant (see the
    /// module docs). The buffer is reused; no allocation happens after
    /// construction.
    pub fn reconstruct(&mut self) -> Result<&[f32]> {
        if self.stages_complete == 0 {
            bail!("no complete stage to reconstruct");
        }
        let cum = self.cum_bits();
        for (i, t) in self.manifest.tensors.iter().enumerate() {
            if self.flat_cum[i] == cum {
                continue;
            }
            let dp = DequantParams::new(&t.quant_params(self.manifest.k), cum);
            dequantize_into(
                &self.q[t.offset..t.offset + t.numel],
                dp,
                &mut self.flat[t.offset..t.offset + t.numel],
            );
            self.flat_cum[i] = cum;
        }
        Ok(&self.flat)
    }

    /// The current flat code vector concatenated across tensors,
    /// borrowed — the fused `qfwd` path consumes it without copying
    /// (dequant runs inside the executable instead).
    pub fn codes_flat(&self) -> &[u32] {
        &self.q
    }

    /// Monotone counter identifying the exact contents of
    /// [`Assembler::codes_flat`]: bumps on every absorbed fragment. Pair
    /// with [`Assembler::cum_bits`] as the backend's qfwd weight-cache
    /// key ([`infer_quantized_versioned`]).
    ///
    /// [`infer_quantized_versioned`]: crate::runtime::ModelSession::infer_quantized_versioned
    ///
    /// Publication safety: `version` is a plain field mutated only under
    /// `&mut self`; cross-thread visibility comes from the lock that owns
    /// the assembler (e.g. `ApproxModel`'s `RwLock` cell), whose
    /// release/acquire edge publishes the bump together with the code
    /// bytes it describes. No atomic is involved, so there is no ordering
    /// to get wrong here — keep it that way.
    pub fn codes_version(&self) -> u64 {
        self.version
    }

    /// Last reconstructed weights without re-running dequant.
    pub fn flat(&self) -> &[f32] {
        &self.flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::header::manifest_from_weights;
    use crate::format::PnetWriter;
    use crate::quant::Schedule;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (PnetWriter, Vec<f32>) {
        let mut r = Rng::new(seed);
        let flat: Vec<f32> = (0..800).map(|_| r.normal() as f32).collect();
        let m = manifest_from_weights(
            "toy",
            "classify",
            &[
                ("w1".to_string(), vec![20, 30]),
                ("b1".to_string(), vec![30]),
                ("w2".to_string(), vec![170]),
            ],
            &flat,
            Schedule::paper_default(),
        )
        .unwrap();
        (PnetWriter::encode(m, &flat).unwrap(), flat)
    }

    #[test]
    fn stage_completion_tracking() {
        let (w, _) = setup(1);
        let mut asm = Assembler::new(w.manifest().clone());
        assert_eq!(asm.stages_complete(), 0);
        assert_eq!(asm.codes_version(), 0);
        // stage 0, tensors 0..2
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), None);
        assert_eq!(asm.absorb(0, 1, w.fragment(0, 1)).unwrap(), None);
        assert_eq!(asm.absorb(0, 2, w.fragment(0, 2)).unwrap(), Some(0));
        assert_eq!(asm.stages_complete(), 1);
        assert_eq!(asm.cum_bits(), 2);
        assert_eq!(asm.codes_version(), 3);
    }

    #[test]
    fn reconstruction_error_shrinks_with_stages() {
        let (w, orig) = setup(2);
        let mut asm = Assembler::new(w.manifest().clone());
        let mut prev = f32::INFINITY;
        for s in 0..8 {
            for t in 0..3 {
                asm.absorb(s, t, w.fragment(s, t)).unwrap();
            }
            let flat = asm.reconstruct().unwrap();
            let err = flat
                .iter()
                .zip(&orig)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(err <= prev + 1e-6);
            prev = err;
        }
        assert!(asm.is_complete());
        // full 16-bit reconstruction: tight error
        let max_range = w
            .manifest()
            .tensors
            .iter()
            .map(|t| t.max - t.min)
            .fold(0f32, f32::max);
        assert!(prev <= max_range / 65536.0 + 1e-6);
    }

    #[test]
    fn eager_dequant_matches_lazy_bit_for_bit() {
        let (w, _) = setup(7);
        let mut eager = Assembler::new(w.manifest().clone());
        eager.set_eager_dequant(true);
        let mut lazy = Assembler::new(w.manifest().clone());
        for s in 0..8 {
            for t in 0..3 {
                eager.absorb(s, t, w.fragment(s, t)).unwrap();
                lazy.absorb(s, t, w.fragment(s, t)).unwrap();
            }
            let a: Vec<u32> = eager.reconstruct().unwrap().iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = lazy.reconstruct().unwrap().iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "stage {s}");
        }
    }

    #[test]
    fn out_of_order_fragment_rejected() {
        let (w, _) = setup(3);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.absorb(1, 0, w.fragment(1, 0)).is_err());
    }

    #[test]
    fn duplicate_fragment_skipped_not_double_counted() {
        let (w, _) = setup(6);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        let codes_before = asm.codes_flat().to_vec();
        let version_before = asm.codes_version();
        // a stage-boundary resume re-delivers stage 0: must be a no-op
        for t in 0..3 {
            assert_eq!(asm.absorb(0, t, w.fragment(0, t)).unwrap(), None);
        }
        assert_eq!(asm.stages_complete(), 1);
        assert_eq!(asm.codes_flat(), &codes_before[..]);
        assert_eq!(asm.codes_version(), version_before);
        // and the next stage still completes normally
        for t in 0..3 {
            asm.absorb(1, t, w.fragment(1, t)).unwrap();
        }
        assert_eq!(asm.stages_complete(), 2);
        assert!(asm.codes_version() > version_before);
    }

    #[test]
    fn reconstruct_before_any_stage_is_error() {
        let (w, _) = setup(4);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.reconstruct().is_err());
    }

    #[test]
    fn codes_flat_matches_accumulators() {
        let (w, _) = setup(5);
        let mut asm = Assembler::new(w.manifest().clone());
        for t in 0..3 {
            asm.absorb(0, t, w.fragment(0, t)).unwrap();
        }
        let codes = asm.codes_flat();
        assert_eq!(codes.len(), 800);
        // stage 0 = top 2 bits only
        assert!(codes.iter().all(|&c| c & 0x3FFF == 0));
    }

    #[test]
    fn wrong_size_plane_rejected() {
        let (w, _) = setup(8);
        let mut asm = Assembler::new(w.manifest().clone());
        assert!(asm.absorb(0, 0, &[0u8; 3]).is_err());
        assert_eq!(asm.stages_complete(), 0);
        assert_eq!(asm.codes_version(), 0);
        // the right-size plane still lands afterwards
        assert_eq!(asm.absorb(0, 0, w.fragment(0, 0)).unwrap(), None);
    }
}
