//! Model registry: discovers every model under `artifacts/models/`.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use super::manifest::ModelManifest;
use crate::util::json::Json;

/// All models known from the artifacts directory.
pub struct Registry {
    models: BTreeMap<String, ModelManifest>,
}

impl Registry {
    /// Scan `artifacts/models/index.json`.
    pub fn open(artifacts_root: &Path) -> Result<Self> {
        let idx = Json::load(&artifacts_root.join("models/index.json"))?;
        let mut models = BTreeMap::new();
        for entry in idx.get("models")?.as_arr()? {
            let name = entry.get("name")?.as_str()?.to_string();
            let dir = artifacts_root.join("models").join(&name);
            let manifest = ModelManifest::load(&dir)?;
            models.insert(name, manifest);
        }
        Ok(Self { models })
    }

    /// Default registry from `artifacts_root()`.
    pub fn open_default() -> Result<Self> {
        Self::open(&crate::artifacts_root())
    }

    pub fn get(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}' (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ModelManifest> {
        self.models.values()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_real_artifacts_if_present() {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = Registry::open_default().unwrap();
        assert!(reg.len() >= 3);
        for name in ["mlp", "cnn", "detector"] {
            let m = reg.get(name).unwrap();
            assert!(m.param_count > 1000);
            let w = m.load_weights().unwrap();
            assert_eq!(w.len(), m.param_count);
            // manifest min/max must match the actual weights
            for t in &m.tensors {
                let seg = &w[t.offset..t.offset + t.numel];
                let lo = seg.iter().copied().fold(f32::INFINITY, f32::min);
                let hi = seg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                assert!((lo - t.min).abs() < 1e-6);
                assert!((hi - t.max).abs() < 1e-6);
            }
        }
        assert!(reg.get("nonexistent").is_err());
    }
}
