//! Model metadata + artifact loading (the AOT bridge's rust half).
//!
//! `python/compile/aot.py` emits, per model, a `manifest.json`, a flat
//! `weights.bin` and HLO-text executables; this module loads them and
//! provides the [`Registry`] used by the server, the coordinator and the
//! evaluation harnesses.

#![forbid(unsafe_code)]

pub mod manifest;
pub mod registry;

pub use manifest::{ModelManifest, TensorInfo};
pub use registry::Registry;
