//! Per-model artifact manifest (`artifacts/models/<name>/manifest.json`).

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::format::header::{manifest_from_weights, PnetManifest};
use crate::quant::Schedule;
use crate::util::bytes;
use crate::util::json::Json;

/// One tensor's metadata as emitted by aot.py.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub offset: usize,
    pub min: f32,
    pub max: f32,
}

/// The full model manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub task: String,
    pub classes: usize,
    pub input_shape: Vec<usize>,
    pub param_count: usize,
    pub k: u32,
    pub default_schedule: Schedule,
    pub tensors: Vec<TensorInfo>,
    /// hlo key (e.g. "fwd_b32") -> file name
    pub hlo: Vec<(String, String)>,
    pub dataset: String,
    dir: PathBuf,
}

impl ModelManifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let j = Json::load(&dir.join("manifest.json"))?;
        let k = j.get("k")?.as_i64()? as u32;
        let widths = j
            .get("default_schedule")?
            .as_arr()?
            .iter()
            .map(|w| Ok(w.as_i64()? as u32))
            .collect::<Result<Vec<_>>>()?;
        let mut tensors = Vec::new();
        for t in j.get("tensors")?.as_arr()? {
            tensors.push(TensorInfo {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<Vec<_>>>()?,
                numel: t.get("numel")?.as_usize()?,
                offset: t.get("offset")?.as_usize()?,
                min: t.get("min")?.as_f64()? as f32,
                max: t.get("max")?.as_f64()? as f32,
            });
        }
        let hlo = j
            .get("hlo")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            classes: j.get("classes")?.as_usize()?,
            input_shape: j
                .get("input_shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            param_count: j.get("param_count")?.as_usize()?,
            k,
            default_schedule: Schedule::new(widths, k)?,
            tensors,
            hlo,
            dataset: j.get("dataset")?.as_str()?.to_string(),
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load the flat f32 weight vector.
    pub fn load_weights(&self) -> Result<Vec<f32>> {
        let flat = bytes::read_f32_file(&self.dir.join("weights.bin"))
            .with_context(|| format!("weights for {}", self.name))?;
        if flat.len() != self.param_count {
            bail!(
                "{}: weights.bin has {} params, manifest says {}",
                self.name,
                flat.len(),
                self.param_count
            );
        }
        Ok(flat)
    }

    /// Path of an HLO artifact by key (e.g. "fwd_b32").
    pub fn hlo_path(&self, key: &str) -> Result<PathBuf> {
        let file = self
            .hlo
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, f)| f)
            .ok_or_else(|| anyhow::anyhow!("{}: no HLO artifact '{key}'", self.name))?;
        Ok(self.dir.join(file))
    }

    /// Largest fwd batch size ≤ `want` available in the artifacts.
    pub fn best_fwd_batch(&self, want: usize) -> Result<usize> {
        let mut best = None;
        for (k, _) in &self.hlo {
            if let Some(b) = k.strip_prefix("fwd_b").and_then(|s| s.parse::<usize>().ok()) {
                if b <= want && best.map_or(true, |cur| b > cur) {
                    best = Some(b);
                }
            }
        }
        best.ok_or_else(|| anyhow::anyhow!("{}: no fwd artifact ≤ batch {want}", self.name))
    }

    /// All available fwd batch sizes, ascending.
    pub fn fwd_batches(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .hlo
            .iter()
            .filter_map(|(k, _)| k.strip_prefix("fwd_b").and_then(|s| s.parse().ok()))
            .collect();
        out.sort_unstable();
        out
    }

    /// Number of output values per sample (classes, +4 box coords for
    /// detection).
    pub fn output_dim(&self) -> usize {
        self.classes + if self.task == "detect" { 4 } else { 0 }
    }

    /// Elements per input sample.
    pub fn input_numel(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Build the `.pnet` wire manifest for this model under a schedule.
    ///
    /// The manifest is layer-annotated (`LayerMajor`): every container
    /// the server/fleet encodes from a registry model carries ragged
    /// per-layer boundaries in its preamble, so clients can stream
    /// execution layer by layer (`SessionEvent::LayerReady`,
    /// `runtime::reference::RefModel::forward_streaming`). The body
    /// bytes are identical to the unannotated encoding.
    pub fn pnet_manifest(&self, flat: &[f32], schedule: Schedule) -> Result<PnetManifest> {
        let tensors: Vec<(String, Vec<usize>)> = self
            .tensors
            .iter()
            .map(|t| (t.name.clone(), t.shape.clone()))
            .collect();
        Ok(
            manifest_from_weights(&self.name, &self.task, &tensors, flat, schedule)?
                .with_inferred_layers(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("prognet-manifest-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        dir
    }

    fn write_fixture(dir: &Path) {
        let manifest = r#"{
            "name": "toy", "task": "classify", "classes": 10,
            "input_shape": [32, 32, 3], "param_count": 6, "k": 16,
            "default_schedule": [2,2,2,2,2,2,2,2],
            "tensors": [
                {"name": "w", "shape": [2,2], "numel": 4, "offset": 0, "min": -1.0, "max": 1.0},
                {"name": "b", "shape": [2], "numel": 2, "offset": 4, "min": 0.0, "max": 0.5}
            ],
            "hlo": {"fwd_b1": "fwd_b1.hlo.txt", "fwd_b32": "fwd_b32.hlo.txt"},
            "weights": "weights.bin", "accuracy": {"top1": 0.9}, "dataset": "shapes10"
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let w: Vec<f32> = vec![-1.0, 0.5, 0.25, 1.0, 0.0, 0.5];
        std::fs::write(dir.join("weights.bin"), crate::util::bytes::f32_to_le(&w)).unwrap();
    }

    #[test]
    fn load_fixture() {
        let dir = fixture_dir();
        write_fixture(&dir);
        let m = ModelManifest::load(&dir).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.param_count, 6);
        assert_eq!(m.tensors.len(), 2);
        assert_eq!(m.load_weights().unwrap().len(), 6);
        assert_eq!(m.best_fwd_batch(100).unwrap(), 32);
        assert_eq!(m.best_fwd_batch(5).unwrap(), 1);
        assert!(m.best_fwd_batch(0).is_err());
        assert_eq!(m.fwd_batches(), vec![1, 32]);
        assert_eq!(m.output_dim(), 10);
        assert_eq!(m.input_numel(), 3072);
        let flat = m.load_weights().unwrap();
        let pm = m
            .pnet_manifest(&flat, crate::quant::Schedule::paper_default())
            .unwrap();
        assert_eq!(pm.param_count(), 6);
        // registry manifests are layer-annotated: w [2,2] + b [2] = 1 layer
        assert_eq!(pm.layers, Some(vec![2]));
    }
}
