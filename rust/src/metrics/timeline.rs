//! Event timeline for progressive sessions — the data behind Fig 4.
//!
//! Both real runs (wall-clock) and simulated runs (virtual time) record
//! the same event stream; the Fig 4 bench renders it as ASCII lanes.

/// What happened at a point in (virtual or wall) time.

#![forbid(unsafe_code)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// transfer of stage `i`'s bytes started
    StageTransferStart,
    /// all of stage `i`'s fragments arrived
    StageTransferDone,
    /// concat+dequant of stage `i` started
    ReconstructStart,
    ReconstructDone,
    /// inference with the stage-`i` approximate model
    InferStart,
    InferDone,
    /// first output shown to the user (per stage)
    OutputReady,
}

/// One timeline record.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub t: f64,
    pub stage: usize,
    pub kind: EventKind,
}

/// An ordered event log.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    events: Vec<Event>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: f64, stage: usize, kind: EventKind) {
        self.events.push(Event { t, stage, kind });
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Time of the first event of `kind` for `stage`.
    pub fn time_of(&self, stage: usize, kind: EventKind) -> Option<f64> {
        self.events
            .iter()
            .find(|e| e.stage == stage && e.kind == kind)
            .map(|e| e.t)
    }

    /// Completion time (max event time).
    pub fn total_time(&self) -> f64 {
        self.events.iter().map(|e| e.t).fold(0.0, f64::max)
    }

    /// Times at which each stage's output became available (Fig 5/6's
    /// "intermediate results at t=…" captions).
    pub fn output_times(&self) -> Vec<(usize, f64)> {
        let mut out: Vec<(usize, f64)> = self
            .events
            .iter()
            .filter(|e| e.kind == EventKind::OutputReady)
            .map(|e| (e.stage, e.t))
            .collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        out
    }

    /// Render as ASCII lanes (one row per stage), `width` columns.
    pub fn render_ascii(&self, width: usize) -> String {
        let total = self.total_time().max(1e-9);
        let stages = self.events.iter().map(|e| e.stage).max().unwrap_or(0) + 1;
        let col = |t: f64| ((t / total) * (width - 1) as f64).round() as usize;
        let mut out = String::new();
        for s in 0..stages {
            let mut row = vec![b'.'; width];
            let mark = |row: &mut Vec<u8>, a: Option<f64>, b: Option<f64>, ch: u8| {
                if let (Some(a), Some(b)) = (a, b) {
                    for c in col(a)..=col(b) {
                        row[c] = ch;
                    }
                }
            };
            mark(
                &mut row,
                self.time_of(s, EventKind::StageTransferStart),
                self.time_of(s, EventKind::StageTransferDone),
                b'=',
            );
            mark(
                &mut row,
                self.time_of(s, EventKind::ReconstructStart),
                self.time_of(s, EventKind::ReconstructDone),
                b'r',
            );
            mark(
                &mut row,
                self.time_of(s, EventKind::InferStart),
                self.time_of(s, EventKind::InferDone),
                b'I',
            );
            if let Some(t) = self.time_of(s, EventKind::OutputReady) {
                row[col(t)] = b'*';
            }
            out.push_str(&format!("stage {s:2} |{}|\n", String::from_utf8(row).unwrap()));
        }
        out.push_str(&format!(
            "            0.0s{:>width$}\n",
            format!("{:.1}s", total),
            width = width - 3
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Timeline {
        let mut t = Timeline::new();
        t.push(0.0, 0, EventKind::StageTransferStart);
        t.push(1.0, 0, EventKind::StageTransferDone);
        t.push(1.0, 0, EventKind::ReconstructStart);
        t.push(1.2, 0, EventKind::ReconstructDone);
        t.push(1.2, 0, EventKind::InferStart);
        t.push(1.5, 0, EventKind::InferDone);
        t.push(1.5, 0, EventKind::OutputReady);
        t.push(1.0, 1, EventKind::StageTransferStart);
        t.push(2.0, 1, EventKind::StageTransferDone);
        t.push(2.5, 1, EventKind::OutputReady);
        t
    }

    #[test]
    fn queries() {
        let t = sample();
        assert_eq!(t.time_of(0, EventKind::OutputReady), Some(1.5));
        assert_eq!(t.total_time(), 2.5);
        assert_eq!(t.output_times(), vec![(0, 1.5), (1, 2.5)]);
    }

    #[test]
    fn ascii_render_has_rows() {
        let t = sample();
        let s = t.render_ascii(40);
        assert_eq!(s.lines().count(), 3);
        assert!(s.contains('='));
        assert!(s.contains('*'));
    }

    #[test]
    fn empty_timeline() {
        let t = Timeline::new();
        assert!(t.is_empty());
        assert_eq!(t.total_time(), 0.0);
    }
}
