//! Markdown/aligned-text table emitter — prints the paper-shaped rows the
//! bench harnesses report (Tables I–III).

/// Simple column-aligned table builder.

#![forbid(unsafe_code)]
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns (markdown-compatible pipes).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Also emit tab-separated values (for plotting scripts).
    pub fn to_tsv(&self) -> String {
        let mut out = self.header.join("\t");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Model", "Size", "Time"]);
        t.row(vec!["mlp".into(), "1.6 MB".into(), "8s".into()]);
        t.row(vec!["widecnn".into(), "2.6 MB".into(), "13s".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.lines().count() >= 4);
        // all pipe-rows have equal width
        let rows: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert!(rows.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn tsv() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_tsv(), "a\tb\n1\t2\n");
    }
}
