//! Metrics: event timelines (Fig 4), histograms and table reporters.

#![forbid(unsafe_code)]

pub mod hist;
pub mod report;
pub mod timeline;

pub use hist::Histogram;
pub use report::Table;
pub use timeline::{Event, EventKind, Timeline};
