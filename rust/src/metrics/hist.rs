//! Fixed-bucket latency histogram (log-spaced), for serving metrics.

/// Log-spaced histogram from 1µs to ~100s.

#![forbid(unsafe_code)]
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    bounds: Vec<f64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 1µs .. ~158s in 1/4-decade steps
        let bounds: Vec<f64> = (0..33).map(|i| 1e-6 * 10f64.powf(i as f64 / 4.0)).collect();
        Self {
            buckets: vec![0; bounds.len() + 1],
            bounds,
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    pub fn record(&mut self, secs: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += secs;
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile from bucket upper bounds (the shared
    /// [`bucket_quantile`](crate::util::stats::bucket_quantile) walk).
    pub fn quantile(&self, q: f64) -> f64 {
        crate::util::stats::bucket_quantile(&self.buckets, &self.bounds, self.count, self.max, q)
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.05005).abs() < 1e-3);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.02 && p50 < 0.12, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= p50);
        assert!(h.max() <= 0.1 + 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.001);
        b.record(0.002);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }
}
