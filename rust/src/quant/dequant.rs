//! Eq. 5 — dequantization with the floor-loss / missing-bits revision.
//!
//! `M' = (max - min) * (q' + 2^{k-c-1}) / 2^k + min` for `c` received
//! cumulative bits. At `c == k` the additive term is `0.5` — exactly the
//! paper's `1/2^{k+1}`-of-range revision for the flooring in Eq. 2; for
//! `c < k` it is the midpoint estimate of the not-yet-received low bits
//! (which makes the reconstruction error bound one quantization step *at
//! the received width*, see tests).

#![forbid(unsafe_code)]

use super::quantize::QuantParams;

/// Scalar parameters of one dequantization pass.
#[derive(Debug, Clone, Copy)]
pub struct DequantParams {
    /// `(max - min) / 2^k`
    pub scale: f32,
    /// tensor minimum
    pub min: f32,
    /// `2^{k-c-1}` (or `0.5` at full width)
    pub half: f32,
}

impl DequantParams {
    pub fn new(qp: &QuantParams, cum_bits: u32) -> Self {
        Self {
            scale: qp.dequant_scale(),
            min: qp.min,
            half: half_correction(qp.k, cum_bits),
        }
    }
}

/// The `2^{k-c-1}` midpoint term of Eq. 5.
pub fn half_correction(k: u32, cum_bits: u32) -> f32 {
    assert!(cum_bits >= 1 && cum_bits <= k);
    if cum_bits >= k {
        0.5
    } else {
        (1u64 << (k - cum_bits - 1)) as f32
    }
}

/// Eq. 5 into a caller-provided buffer — the per-stage hot path.
///
/// A single fused multiply-add per element; the compiler auto-vectorizes
/// this loop (see EXPERIMENTS.md §Perf).
pub fn dequantize_into(q: &[u32], p: DequantParams, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    let DequantParams { scale, min, half } = p;
    for (o, &v) in out.iter_mut().zip(q) {
        *o = (v as f32 + half) * scale + min;
    }
}

/// Allocating convenience wrapper.
pub fn dequantize(q: &[u32], p: DequantParams) -> Vec<f32> {
    let mut out = vec![0f32; q.len()];
    dequantize_into(q, p, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitplane::encode_planes;
    use crate::quant::concat::Accumulator;
    use crate::quant::quantize::{quantize, QuantParams, K};
    use crate::quant::schedule::Schedule;
    use crate::util::rng::Rng;

    fn tensor(seed: u64, n: usize, scale: f64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| (r.normal() * scale) as f32).collect()
    }

    /// One quantization step at `c` received bits.
    fn step(qp: &QuantParams, c: u32) -> f32 {
        ((qp.max as f64 - qp.min as f64 + qp.eps()) / (1u64 << c) as f64) as f32
    }

    #[test]
    fn full_roundtrip_error_half_step() {
        let data = tensor(1, 8192, 0.4);
        let qp = QuantParams::from_data(&data, K);
        let q = quantize(&data, &qp);
        let out = dequantize(&q, DequantParams::new(&qp, K));
        let max_err = data
            .iter()
            .zip(&out)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // half a step plus f32 rounding slack (dequant runs in f32; the
        // intermediate (q+0.5)*scale is O(range), so allow a few ulps)
        let slack = (qp.max - qp.min).abs() * 1e-6 + 1e-7;
        assert!(max_err <= 0.5 * step(&qp, K) + slack, "err {max_err}");
    }

    #[test]
    fn progressive_error_decreases() {
        let data = tensor(2, 4096, 1.3);
        let qp = QuantParams::from_data(&data, K);
        let q = quantize(&data, &qp);
        let sched = Schedule::paper_default();
        let planes = encode_planes(&q, &sched);
        let mut acc = Accumulator::new(q.len(), sched.clone());
        let mut prev = f32::INFINITY;
        let mut out = vec![0f32; q.len()];
        for (i, p) in planes.iter().enumerate() {
            acc.absorb(p).unwrap();
            let c = sched.cum_bits(i);
            dequantize_into(acc.codes(), DequantParams::new(&qp, c), &mut out);
            let max_err = data
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err <= step(&qp, c), "stage {i}: {max_err} > step");
            assert!(max_err <= prev + 1e-6, "error must not grow");
            prev = max_err;
        }
    }

    #[test]
    fn half_correction_values() {
        assert_eq!(half_correction(16, 16), 0.5);
        assert_eq!(half_correction(16, 2), 8192.0);
        assert_eq!(half_correction(16, 15), 1.0);
    }

    #[test]
    fn midpoint_beats_no_correction_on_average() {
        // The Eq. 5 revision term must reduce the mean error vs raw
        // truncation — this is the paper's justification for flooring.
        let data = tensor(3, 20_000, 0.7);
        let qp = QuantParams::from_data(&data, K);
        let q = quantize(&data, &qp);
        let c = 4u32;
        let trunc: Vec<u32> = q.iter().map(|v| v & !((1 << (K - c)) - 1)).collect();
        let with = dequantize(&trunc, DequantParams::new(&qp, c));
        let without = dequantize(
            &trunc,
            DequantParams {
                half: 0.0,
                ..DequantParams::new(&qp, c)
            },
        );
        let mean = |xs: &[f32]| -> f64 {
            xs.iter()
                .zip(&data)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / xs.len() as f64
        };
        assert!(mean(&with) < mean(&without) * 0.6);
    }

    #[test]
    fn degenerate_tensor_reconstructs_constant() {
        let data = vec![-1.25f32; 33];
        let qp = QuantParams::from_data(&data, K);
        let q = quantize(&data, &qp);
        let out = dequantize(&q, DequantParams::new(&qp, K));
        for v in out {
            assert!((v - -1.25).abs() < 1e-5);
        }
    }
}
