//! Eq. 3 — bit division of k-bit codes into fraction planes, with tight
//! MSB-first bit-packing for the wire (the transmitted representation).
//!
//! Packing contract (shared with `python/compile/aot.py::pack_plane_np`
//! and asserted against `artifacts/golden/plane*.bin`): values are packed
//! most-significant-bit first, in element order, with the final partial
//! byte zero-padded on the right. A plane of `n` elements at width `w`
//! occupies exactly `ceil(n*w / 8)` bytes — so the sum over a schedule's
//! planes equals the singleton 16-bit size (plus ≤1 ragged byte/plane):
//! progressive transmission does not inflate the model.

#![forbid(unsafe_code)]

use super::schedule::Schedule;

/// Extract the stage-`m` fraction plane from full codes (Eq. 3), unpacked.
pub fn split_plane(q: &[u32], sched: &Schedule, stage: usize) -> Vec<u32> {
    let mut out = vec![0u32; q.len()];
    split_plane_into(q, sched, stage, &mut out);
    out
}

/// [`split_plane`] into caller-provided scratch — the encoder's per-stage
/// loop reuses one buffer across all stages instead of allocating a
/// fresh plane each time.
pub fn split_plane_into(q: &[u32], sched: &Schedule, stage: usize, out: &mut [u32]) {
    debug_assert_eq!(q.len(), out.len());
    let k = sched.k();
    let w = sched.widths()[stage];
    let cum = sched.cum_bits(stage);
    let mask = (1u32 << w) - 1;
    let shift = k - cum;
    for (o, &v) in out.iter_mut().zip(q) {
        *o = (v >> shift) & mask;
    }
}

/// Pack an unpacked plane (values < 2^w) into tight MSB-first bytes.
pub fn pack_plane(values: &[u32], width: u32) -> Vec<u8> {
    assert!((1..=16).contains(&width));
    let total_bits = values.len() * width as usize;
    let mut out = Vec::with_capacity((total_bits + 7) / 8);
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mask = (1u64 << width) - 1;
    for &v in values {
        acc = (acc << width) | (v as u64 & mask);
        nbits += width;
        while nbits >= 8 {
            nbits -= 8;
            out.push(((acc >> nbits) & 0xFF) as u8);
        }
    }
    if nbits > 0 {
        out.push(((acc << (8 - nbits)) & 0xFF) as u8);
    }
    out
}

/// Unpack a tight plane back to one value per element.
pub fn unpack_plane(bytes: &[u8], width: u32, numel: usize) -> Vec<u32> {
    let mut out = vec![0u32; numel];
    unpack_plane_into(bytes, width, &mut out);
    out
}

/// In-place unpack — part of the client's per-stage hot path.
pub fn unpack_plane_into(bytes: &[u8], width: u32, out: &mut [u32]) {
    unpack_or_into(bytes, width, 0, true, out)
}

/// Fused Eq. 3⁻¹ + Eq. 4 inner loop: unpack the plane and OR each value,
/// shifted by `shift`, into `out` (or overwrite when `replace`).
///
/// This is the client's per-stage hot path; byte-aligned widths (1, 2, 4,
/// 8, 16) take branch-free unrolled fast paths — one input byte expands
/// to a fixed number of outputs with no carried bit state — and the
/// generic path handles ragged widths. See EXPERIMENTS.md §Perf.
pub fn unpack_or_into(bytes: &[u8], width: u32, shift: u32, replace: bool, out: &mut [u32]) {
    assert!((1..=16).contains(&width));
    debug_assert!(bytes.len() >= (out.len() * width as usize + 7) / 8);
    macro_rules! aligned {
        ($per_byte:expr, $w:expr) => {{
            let mut chunks = out.chunks_exact_mut($per_byte);
            let mask = (1u32 << $w) - 1;
            for (chunk, &b) in (&mut chunks).zip(bytes) {
                let b = b as u32;
                for (j, o) in chunk.iter_mut().enumerate() {
                    let v = (b >> (8 - $w - j as u32 * $w)) & mask;
                    if replace {
                        *o = v << shift;
                    } else {
                        *o |= v << shift;
                    }
                }
            }
            // ragged tail (fewer than $per_byte outputs from the last byte).
            // Index the plane's own tail byte — the one right after the
            // full chunks — NOT `bytes.len() - 1`: the caller's buffer may
            // legally extend past the plane (see the debug_assert above),
            // and the buffer's last byte is then unrelated data.
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                let b = bytes[out.len() / $per_byte] as u32;
                for (j, o) in rem.iter_mut().enumerate() {
                    let v = (b >> (8 - $w - j as u32 * $w)) & mask;
                    if replace {
                        *o = v << shift;
                    } else {
                        *o |= v << shift;
                    }
                }
            }
        }};
    }
    match width {
        1 => aligned!(8, 1),
        2 => aligned!(4, 2),
        4 => aligned!(2, 4),
        8 => {
            for (o, &b) in out.iter_mut().zip(bytes) {
                let v = b as u32;
                if replace {
                    *o = v << shift;
                } else {
                    *o |= v << shift;
                }
            }
        }
        16 => {
            for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                let v = ((b[0] as u32) << 8) | b[1] as u32;
                if replace {
                    *o = v << shift;
                } else {
                    *o |= v << shift;
                }
            }
        }
        _ => {
            // generic bit-carry path for ragged widths (3, 5, 6, ...)
            let mask = (1u64 << width) - 1;
            let mut acc: u64 = 0;
            let mut nbits: u32 = 0;
            let mut bi = 0;
            for o in out.iter_mut() {
                while nbits < width {
                    acc = (acc << 8) | bytes[bi] as u64;
                    bi += 1;
                    nbits += 8;
                }
                nbits -= width;
                let v = ((acc >> nbits) & mask) as u32;
                if replace {
                    *o = v << shift;
                } else {
                    *o |= v << shift;
                }
            }
        }
    }
}

/// Split + pack all stages of a tensor (the encoder path). One unpacked
/// scratch plane is reused across every stage; the only allocations are
/// the packed outputs themselves.
pub fn encode_planes(q: &[u32], sched: &Schedule) -> Vec<Vec<u8>> {
    let mut scratch = vec![0u32; q.len()];
    (0..sched.stages())
        .map(|s| {
            split_plane_into(q, sched, s, &mut scratch);
            pack_plane(&scratch, sched.widths()[s])
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize::{quantize, QuantParams, K};
    use crate::util::rng::Rng;

    fn codes(seed: u64, n: usize) -> Vec<u32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| (r.next_u64() & 0xFFFF) as u32).collect()
    }

    #[test]
    fn known_vectors() {
        // Matches python test_pack_plane_known_vector.
        assert_eq!(pack_plane(&[0, 1, 2, 3], 2), vec![0x1b]);
        assert_eq!(pack_plane(&[0xA, 0xB, 0xC], 4), vec![0xAB, 0xC0]);
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        for width in 1..=16u32 {
            for n in [1usize, 7, 8, 63, 64, 1000] {
                let vals: Vec<u32> = codes(width as u64 * 100 + n as u64, n)
                    .iter()
                    .map(|v| v & ((1 << width) - 1))
                    .collect();
                let packed = pack_plane(&vals, width);
                assert_eq!(packed.len(), (n * width as usize + 7) / 8);
                assert_eq!(unpack_plane(&packed, width, n), vals);
            }
        }
    }

    #[test]
    fn ragged_tail_uses_plane_byte_not_buffer_tail() {
        // Regression: with a buffer longer than the exact plane (which the
        // debug_assert explicitly permits), the ragged-tail fast path read
        // `bytes[bytes.len() - 1]` — a byte that is not part of the plane.
        for width in [1u32, 2, 4] {
            let per_byte = (8 / width) as usize;
            for extra in [1usize, 3] {
                let n = per_byte * 3 + 1; // one ragged element in the tail
                let vals: Vec<u32> = (0..n as u32).map(|v| v & ((1 << width) - 1)).collect();
                let mut packed = pack_plane(&vals, width);
                // caller's buffer extends past the plane with unrelated bytes
                packed.resize(packed.len() + extra, 0xFF);
                let mut out = vec![0u32; n];
                unpack_plane_into(&packed, width, &mut out);
                assert_eq!(out, vals, "width {width}, {extra} trailing bytes");
                // OR-mode must see the same plane values too
                let mut acc = vec![0u32; n];
                unpack_or_into(&packed, width, 4, false, &mut acc);
                let expect: Vec<u32> = vals.iter().map(|v| v << 4).collect();
                assert_eq!(acc, expect, "width {width} or-mode");
            }
        }
    }

    #[test]
    fn split_planes_reassemble() {
        let q = codes(5, 4096);
        for sched in [
            Schedule::paper_default(),
            Schedule::new(vec![4; 4], K).unwrap(),
            Schedule::new(vec![1, 1, 2, 4, 8], K).unwrap(),
            Schedule::singleton(),
        ] {
            let mut acc = vec![0u32; q.len()];
            for s in 0..sched.stages() {
                let plane = split_plane(&q, &sched, s);
                let shift = sched.k() - sched.cum_bits(s);
                for (a, p) in acc.iter_mut().zip(&plane) {
                    *a |= p << shift;
                }
            }
            assert_eq!(acc, q, "schedule {sched}");
        }
    }

    #[test]
    fn planes_fit_width() {
        let q = codes(9, 512);
        let sched = Schedule::paper_default();
        for s in 0..sched.stages() {
            let plane = split_plane(&q, &sched, s);
            let w = sched.widths()[s];
            assert!(plane.iter().all(|&v| v < (1 << w)));
        }
    }

    #[test]
    fn encode_planes_sizes() {
        let data: Vec<f32> = {
            let mut r = Rng::new(11);
            (0..10_007).map(|_| r.normal() as f32).collect()
        };
        let p = QuantParams::from_data(&data, K);
        let q = quantize(&data, &p);
        let sched = Schedule::paper_default();
        let planes = encode_planes(&q, &sched);
        let total: usize = planes.iter().map(|p| p.len()).sum();
        let singleton = (data.len() * 16 + 7) / 8;
        assert!(total <= singleton + sched.stages());
    }

    #[test]
    fn first_plane_is_msbs() {
        let q = vec![0xFFFFu32, 0x0000, 0x8000, 0x4000];
        let sched = Schedule::paper_default();
        assert_eq!(split_plane(&q, &sched, 0), vec![3, 0, 2, 1]);
    }
}
