//! §III-A — the naive digit-split baseline (Eq. 1).
//!
//! Represents each float as decimal significand digits + exponent and
//! transmits significand digits progressively. This is the strawman the
//! paper rejects: it is "not efficient in terms of representation space".
//! We implement it to regenerate that ablation (`ablation_naive_split`
//! bench): bytes-per-stage vs reconstruction error, compared with the
//! quantization bit-split codec.
//!
//! Encoding: for each value, `d` decimal digits of the significand plus a
//! shared per-value exponent byte (sign packed into it). A stage carries
//! `digits_per_stage` digits per value, each digit packed in 4 bits (BCD),
//! so stage size is `numel * digits/2` bytes plus the one-off exponent
//! plane — strictly larger than the bit-split's `numel * w / 8`.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

/// Total significand digits carried (≈ f32 precision).
pub const TOTAL_DIGITS: usize = 8;

/// Naive-split encoder state for one tensor.
#[derive(Debug, Clone)]
pub struct NaiveEncoded {
    /// per-value sign (1 bit, packed) + exponent (i8) plane
    pub exponents: Vec<u8>,
    pub signs: Vec<u8>,
    /// per-stage BCD digit planes, MSB digit first
    pub digit_planes: Vec<Vec<u8>>,
    pub digits_per_stage: usize,
    pub numel: usize,
}

/// Encode a tensor with `stages` equal digit groups.
pub fn encode(data: &[f32], stages: usize) -> Result<NaiveEncoded> {
    if stages == 0 || TOTAL_DIGITS % stages != 0 {
        bail!("stages must evenly divide {TOTAL_DIGITS}");
    }
    let digits_per_stage = TOTAL_DIGITS / stages;
    let mut exponents = Vec::with_capacity(data.len());
    let mut signs = vec![0u8; (data.len() + 7) / 8];
    let mut all_digits: Vec<[u8; TOTAL_DIGITS]> = Vec::with_capacity(data.len());

    for (i, &v) in data.iter().enumerate() {
        if v < 0.0 {
            signs[i / 8] |= 1 << (i % 8);
        }
        let a = v.abs() as f64;
        let exp = if a == 0.0 { 0 } else { a.log10().floor() as i32 };
        let exp = exp.clamp(-64, 63);
        exponents.push((exp + 64) as u8);
        // significand in [1, 10): first digit is the leading digit
        let mut sig = if a == 0.0 { 0.0 } else { a / 10f64.powi(exp) };
        let mut digits = [0u8; TOTAL_DIGITS];
        for d in digits.iter_mut() {
            let dig = sig.floor().clamp(0.0, 9.0);
            *d = dig as u8;
            sig = (sig - dig) * 10.0;
        }
        all_digits.push(digits);
    }

    // BCD-pack each stage's digit group.
    let mut digit_planes = Vec::with_capacity(stages);
    for s in 0..stages {
        let lo = s * digits_per_stage;
        let mut plane = Vec::with_capacity((data.len() * digits_per_stage + 1) / 2);
        let mut nibble_pending: Option<u8> = None;
        for digits in &all_digits {
            for d in &digits[lo..lo + digits_per_stage] {
                match nibble_pending.take() {
                    None => nibble_pending = Some(*d),
                    Some(hi) => plane.push((hi << 4) | d),
                }
            }
        }
        if let Some(hi) = nibble_pending {
            plane.push(hi << 4);
        }
        digit_planes.push(plane);
    }

    Ok(NaiveEncoded {
        exponents,
        signs,
        digit_planes,
        digits_per_stage,
        numel: data.len(),
    })
}

impl NaiveEncoded {
    /// Wire bytes of stage `s` (stage 0 additionally carries sign+exponent).
    pub fn stage_bytes(&self, s: usize) -> usize {
        let base = self.digit_planes[s].len();
        if s == 0 {
            base + self.exponents.len() + self.signs.len()
        } else {
            base
        }
    }

    pub fn total_bytes(&self) -> usize {
        (0..self.digit_planes.len()).map(|s| self.stage_bytes(s)).sum()
    }

    /// Reconstruct after receiving the first `stages_received` stages.
    pub fn decode(&self, stages_received: usize) -> Vec<f32> {
        let mut out = vec![0f32; self.numel];
        let ndig = stages_received * self.digits_per_stage;
        // unpack received digit nibbles per value
        for (i, o) in out.iter_mut().enumerate() {
            let mut sig = 0f64;
            let mut weight = 1f64;
            for s in 0..stages_received {
                let plane = &self.digit_planes[s];
                for d in 0..self.digits_per_stage {
                    let idx = i * self.digits_per_stage + d;
                    let byte = plane[idx / 2];
                    let dig = if idx % 2 == 0 { byte >> 4 } else { byte & 0xF };
                    sig += dig as f64 * weight;
                    weight /= 10.0;
                }
            }
            if ndig > 0 {
                // midpoint of the unreceived digit range
                sig += 0.5 * weight * 10.0 / 9.0 * 4.5;
            }
            let exp = self.exponents[i] as i32 - 64;
            let neg = (self.signs[i / 8] >> (i % 8)) & 1 == 1;
            let v = sig * 10f64.powi(exp);
            *o = if neg { -(v as f32) } else { v as f32 };
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| (r.normal() * 0.5) as f32).collect()
    }

    #[test]
    fn full_decode_accurate() {
        let data = tensor(1, 500);
        let enc = encode(&data, 4).unwrap();
        let out = enc.decode(4);
        for (a, b) in data.iter().zip(&out) {
            assert!(
                (a - b).abs() <= a.abs() * 1e-5 + 1e-7,
                "{a} vs {b}"
            );
        }
    }

    #[test]
    fn progressive_decode_improves() {
        let data = tensor(2, 1000);
        let enc = encode(&data, 4).unwrap();
        let mut prev = f64::INFINITY;
        for s in 1..=4 {
            let out = enc.decode(s);
            let mean: f64 = data
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / data.len() as f64;
            assert!(mean <= prev, "stage {s}: {mean} > {prev}");
            prev = mean;
        }
    }

    #[test]
    fn representation_is_larger_than_bitsplit() {
        // The paper's point: digit splitting wastes representation space.
        use crate::quant::{quantize, QuantParams, Schedule, K};
        let data = tensor(3, 10_000);
        let enc = encode(&data, 4).unwrap();
        let qp = QuantParams::from_data(&data, K);
        let q = quantize::quantize(&data, &qp);
        let sched = Schedule::new(vec![4; 4], K).unwrap();
        let bitsplit_total: usize = crate::quant::bitplane::encode_planes(&q, &sched)
            .iter()
            .map(|p| p.len())
            .sum();
        assert!(
            enc.total_bytes() as f64 > bitsplit_total as f64 * 1.5,
            "naive {} vs bitsplit {}",
            enc.total_bytes(),
            bitsplit_total
        );
        let _ = q;
    }

    #[test]
    fn stage_sizes_reported() {
        let data = tensor(4, 128);
        let enc = encode(&data, 2).unwrap();
        assert_eq!(enc.total_bytes(), enc.stage_bytes(0) + enc.stage_bytes(1));
        assert!(enc.stage_bytes(0) > enc.stage_bytes(1)); // exponent plane
    }

    #[test]
    fn invalid_stage_counts() {
        assert!(encode(&[1.0], 3).is_err());
        assert!(encode(&[1.0], 0).is_err());
    }

    #[test]
    fn zero_and_negative_values() {
        let data = vec![0.0f32, -1.5, 2.25e-3, -7.75e2];
        let enc = encode(&data, 2).unwrap();
        let out = enc.decode(2);
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() <= a.abs() * 1e-5 + 1e-7, "{a} vs {b}");
        }
    }
}
