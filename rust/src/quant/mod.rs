//! The paper's codec: quantization (Eq. 2), bit division (Eq. 3),
//! bit concatenation (Eq. 4) and dequantization (Eq. 5), plus bit-width
//! schedules and the §III-A naive digit-split baseline.
//!
//! Specification (mirrored in `python/compile/kernels/ref.py`, and
//! cross-checked against `artifacts/golden/`):
//!
//! - `k = 16` bit unsigned quantization per tensor.
//! - Eq. 2: `q = floor(2^k * (M - min) / (max - min + eps))` in f64, with
//!   `eps = max((max-min) * 1e-6, 1e-12)`; constant tensors map to 0.
//! - Eq. 3: part *m* of schedule widths `b` holds bits
//!   `[k - c_m, k - c_{m-1})` of `q` (MSB first), `c_m = b_1 + … + b_m`.
//! - Eq. 4: `q' = OR_m (p_m << (k - c_m))` — implemented incrementally in
//!   [`concat::Accumulator`].
//! - Eq. 5: `M' = (max-min) * (q' + 2^{k-c-1}) / 2^k + min` after `c`
//!   received bits; at `c = k` the additive term is the paper's floor-loss
//!   revision `(max-min)/2^{k+1}`.

#![forbid(unsafe_code)]

pub mod bitplane;
pub mod concat;
pub mod dequant;
pub mod naive;
pub mod quantize;
pub mod schedule;

pub use bitplane::{pack_plane, split_plane, split_plane_into, unpack_or_into, unpack_plane};
pub use concat::Accumulator;
pub use dequant::{dequantize_into, half_correction, DequantParams};
pub use quantize::{quantize, QuantParams, K};
pub use schedule::Schedule;
