//! Bit-width schedules — the user-facing `b` configuration of §III-B.
//!
//! A schedule is the list of per-stage bit-widths, e.g. the paper's
//! default `[2,2,2,2,2,2,2,2]` (2→4→…→16). Widths must sum to `k`.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use super::quantize::K;

/// A validated progressive bit-width schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schedule {
    widths: Vec<u32>,
    k: u32,
}

impl Schedule {
    /// Build and validate a schedule for depth `k`.
    pub fn new(widths: Vec<u32>, k: u32) -> Result<Self> {
        if widths.is_empty() {
            bail!("schedule must have at least one stage");
        }
        if widths.iter().any(|&w| w == 0 || w > k) {
            bail!("stage widths must be in [1, {k}]: {widths:?}");
        }
        let total: u32 = widths.iter().sum();
        if total != k {
            bail!("schedule widths {widths:?} sum to {total}, expected {k}");
        }
        Ok(Self { widths, k })
    }

    /// The paper's default 8-stage schedule (2→4→…→16).
    pub fn paper_default() -> Self {
        Self::new(vec![2; 8], K).unwrap()
    }

    /// Single-stage schedule == non-progressive ("singleton") transmission.
    pub fn singleton() -> Self {
        Self::new(vec![K], K).unwrap()
    }

    /// Parse "2,2,4,8"-style text (CLI).
    pub fn parse(text: &str, k: u32) -> Result<Self> {
        let widths = text
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse::<u32>().map_err(anyhow::Error::from))
            .collect::<Result<Vec<_>>>()?;
        Self::new(widths, k)
    }

    pub fn widths(&self) -> &[u32] {
        &self.widths
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn stages(&self) -> usize {
        self.widths.len()
    }

    /// Cumulative bits after stage `i` (0-based).
    pub fn cum_bits(&self, stage: usize) -> u32 {
        self.widths[..=stage].iter().sum()
    }

    /// All cumulative widths, e.g. [2,4,6,...,16].
    pub fn cum_all(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.widths.len());
        let mut c = 0;
        for &w in &self.widths {
            c += w;
            out.push(c);
        }
        out
    }

    /// Bytes of stage `i`'s plane for a tensor with `numel` elements
    /// (tight MSB-first packing).
    pub fn plane_bytes(&self, stage: usize, numel: usize) -> usize {
        (numel * self.widths[stage] as usize + 7) / 8
    }

    /// Total payload bytes across all stages for `numel` elements.
    pub fn total_bytes(&self, numel: usize) -> usize {
        (0..self.stages()).map(|s| self.plane_bytes(s, numel)).sum()
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self.cum_all().iter().map(|c| c.to_string()).collect();
        write!(f, "{}", parts.join("→"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_8_stage() {
        let s = Schedule::paper_default();
        assert_eq!(s.stages(), 8);
        assert_eq!(s.cum_all(), vec![2, 4, 6, 8, 10, 12, 14, 16]);
        assert_eq!(s.to_string(), "2→4→6→8→10→12→14→16");
    }

    #[test]
    fn validation() {
        assert!(Schedule::new(vec![], K).is_err());
        assert!(Schedule::new(vec![8, 9], K).is_err());
        assert!(Schedule::new(vec![0, 16], K).is_err());
        assert!(Schedule::new(vec![4, 4, 4, 4], K).is_ok());
    }

    #[test]
    fn parse_text() {
        let s = Schedule::parse("1,1,2,4,8", K).unwrap();
        assert_eq!(s.cum_all(), vec![1, 2, 4, 8, 16]);
        assert!(Schedule::parse("3,3", K).is_err());
        assert!(Schedule::parse("a,b", K).is_err());
    }

    #[test]
    fn sizes_no_inflation() {
        // The paper's claim: progressive representation does not increase
        // total size (up to one ragged byte per stage).
        let s = Schedule::paper_default();
        let numel = 10_007;
        let singleton = (numel * 16 + 7) / 8;
        assert!(s.total_bytes(numel) <= singleton + s.stages());
        assert_eq!(s.plane_bytes(0, 4), 1);
    }
}
