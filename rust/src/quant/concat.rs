//! Eq. 4 — incremental bit concatenation on the client.
//!
//! The client keeps one [`Accumulator`] per tensor; each arriving packed
//! plane is unpacked and OR-ed into the k-bit code buffer in place. This
//! is the first half of the per-stage reconstruct hot path (the second is
//! Eq. 5 in [`super::dequant`]).

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use super::bitplane;
use super::schedule::Schedule;

/// Incremental Eq. 4 state for one tensor.
#[derive(Debug, Clone)]
pub struct Accumulator {
    q: Vec<u32>,
    sched: Schedule,
    next_stage: usize,
}

impl Accumulator {
    pub fn new(numel: usize, sched: Schedule) -> Self {
        Self {
            q: vec![0u32; numel],
            sched,
            next_stage: 0,
        }
    }

    /// Reset to the empty state (reuse buffers for a fresh download).
    pub fn reset(&mut self) {
        self.q.fill(0);
        self.next_stage = 0;
    }

    /// Number of stages absorbed so far.
    pub fn stages_received(&self) -> usize {
        self.next_stage
    }

    /// Cumulative bits received.
    pub fn cum_bits(&self) -> u32 {
        if self.next_stage == 0 {
            0
        } else {
            self.sched.cum_bits(self.next_stage - 1)
        }
    }

    pub fn is_complete(&self) -> bool {
        self.next_stage == self.sched.stages()
    }

    pub fn numel(&self) -> usize {
        self.q.len()
    }

    /// Absorb the next packed plane (must arrive in schedule order).
    pub fn absorb(&mut self, packed: &[u8]) -> Result<()> {
        if self.is_complete() {
            bail!("all {} stages already received", self.sched.stages());
        }
        let stage = self.next_stage;
        let w = self.sched.widths()[stage];
        let expect = self.sched.plane_bytes(stage, self.q.len());
        if packed.len() != expect {
            bail!(
                "stage {stage} plane is {} bytes, expected {expect}",
                packed.len()
            );
        }
        let shift = self.sched.k() - self.sched.cum_bits(stage);
        // Fused unpack + shift + OR — single pass, no scratch buffer.
        // Stage 0 can overwrite instead of OR (q is all-zero then).
        bitplane::unpack_or_into(packed, w, shift, stage == 0, &mut self.q);
        self.next_stage += 1;
        Ok(())
    }

    /// Current (partially filled) k-bit codes.
    pub fn codes(&self) -> &[u32] {
        &self.q
    }

    pub fn schedule(&self) -> &Schedule {
        &self.sched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitplane::{encode_planes, split_plane, pack_plane};
    use crate::quant::quantize::K;
    use crate::util::rng::Rng;

    fn codes(seed: u64, n: usize) -> Vec<u32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| (r.next_u64() & 0xFFFF) as u32).collect()
    }

    #[test]
    fn full_reassembly_matches() {
        let q = codes(1, 3001);
        for sched in [
            Schedule::paper_default(),
            Schedule::new(vec![8, 8], K).unwrap(),
            Schedule::singleton(),
        ] {
            let planes = encode_planes(&q, &sched);
            let mut acc = Accumulator::new(q.len(), sched.clone());
            for p in &planes {
                acc.absorb(p).unwrap();
            }
            assert!(acc.is_complete());
            assert_eq!(acc.codes(), &q[..]);
        }
    }

    #[test]
    fn partial_has_high_bits_only() {
        let q = codes(2, 256);
        let sched = Schedule::paper_default();
        let planes = encode_planes(&q, &sched);
        let mut acc = Accumulator::new(q.len(), sched.clone());
        acc.absorb(&planes[0]).unwrap();
        acc.absorb(&planes[1]).unwrap();
        assert_eq!(acc.cum_bits(), 4);
        for (a, orig) in acc.codes().iter().zip(&q) {
            assert_eq!(*a, orig & 0xF000);
        }
    }

    #[test]
    fn wrong_size_plane_rejected() {
        let sched = Schedule::paper_default();
        let mut acc = Accumulator::new(100, sched);
        assert!(acc.absorb(&[0u8; 3]).is_err()); // expect ceil(100*2/8)=25
        assert_eq!(acc.stages_received(), 0);
    }

    #[test]
    fn absorb_past_end_rejected() {
        let q = codes(3, 16);
        let sched = Schedule::new(vec![16], K).unwrap();
        let planes = encode_planes(&q, &sched);
        let mut acc = Accumulator::new(16, sched);
        acc.absorb(&planes[0]).unwrap();
        assert!(acc.absorb(&planes[0]).is_err());
    }

    #[test]
    fn monotone_code_refinement() {
        // Each stage can only add lower-order bits: codes are monotonically
        // non-decreasing and never exceed the final value.
        let q = codes(4, 512);
        let sched = Schedule::paper_default();
        let planes = encode_planes(&q, &sched);
        let mut acc = Accumulator::new(q.len(), sched);
        let mut prev = vec![0u32; q.len()];
        for p in &planes {
            acc.absorb(p).unwrap();
            for ((cur, pv), fin) in acc.codes().iter().zip(&prev).zip(&q) {
                assert!(cur >= pv);
                assert!(cur <= fin);
            }
            prev = acc.codes().to_vec();
        }
    }

    #[test]
    fn stage_planes_independent_of_split_order() {
        let q = codes(5, 128);
        let sched = Schedule::new(vec![4, 4, 4, 4], K).unwrap();
        for s in 0..sched.stages() {
            let direct = pack_plane(&split_plane(&q, &sched, s), 4);
            assert_eq!(direct, encode_planes(&q, &sched)[s]);
        }
    }
}
