//! Eq. 2 — floor quantization of a float tensor to k-bit unsigned codes.

/// Fixed quantization depth used throughout the paper (16-bit models show
/// accuracy equivalent to full precision — §IV-A).

#![forbid(unsafe_code)]
pub const K: u32 = 16;

/// Per-tensor quantization parameters (stored in manifests / `.pnet`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub min: f32,
    pub max: f32,
    pub k: u32,
}

impl QuantParams {
    /// Compute min/max from data.
    pub fn from_data(data: &[f32], k: u32) -> Self {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if data.is_empty() {
            lo = 0.0;
            hi = 0.0;
        }
        Self { min: lo, max: hi, k }
    }

    /// `eps` of Eq. 2 — keeps the scaled range strictly below `2^k`.
    pub fn eps(&self) -> f64 {
        ((self.max as f64 - self.min as f64) * 1e-6).max(1e-12)
    }

    /// Quantization scale `2^k / (max - min + eps)`.
    pub fn scale(&self) -> f64 {
        (1u64 << self.k) as f64 / (self.max as f64 - self.min as f64 + self.eps())
    }

    /// Dequantization step `(max - min) / 2^k`.
    pub fn dequant_scale(&self) -> f32 {
        ((self.max as f64 - self.min as f64) / (1u64 << self.k) as f64) as f32
    }

    pub fn is_degenerate(&self) -> bool {
        self.max <= self.min
    }
}

/// Eq. 2 over a tensor; returns codes in `[0, 2^k)`.
///
/// f64 arithmetic matches the canonical python encoder bit-exactly
/// (`ref.quantize_np`), which the golden vectors assert.
pub fn quantize(data: &[f32], p: &QuantParams) -> Vec<u32> {
    let mut out = vec![0u32; data.len()];
    quantize_into(data, p, &mut out);
    out
}

/// In-place variant for the encode hot path.
pub fn quantize_into(data: &[f32], p: &QuantParams, out: &mut [u32]) {
    assert_eq!(data.len(), out.len());
    if p.is_degenerate() {
        out.fill(0);
        return;
    }
    let scale = p.scale();
    let lo = p.min as f64;
    let top = (1u64 << p.k) as f64 - 1.0;
    for (o, &v) in out.iter_mut().zip(data) {
        let q = ((v as f64 - lo) * scale).floor();
        *o = q.clamp(0.0, top) as u32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_ms(0.0, 0.3) as f32).collect()
    }

    #[test]
    fn range_and_extremes() {
        let data = tensor(1, 4096);
        let p = QuantParams::from_data(&data, K);
        let q = quantize(&data, &p);
        let (imin, _) = data
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let (imax, _) = data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert_eq!(q[imin], 0);
        assert_eq!(q[imax], (1 << K) - 1);
        assert!(q.iter().all(|&v| v < (1 << K)));
    }

    #[test]
    fn monotone() {
        let data = tensor(2, 1000);
        let p = QuantParams::from_data(&data, K);
        let q = quantize(&data, &p);
        let mut idx: Vec<usize> = (0..data.len()).collect();
        idx.sort_by(|&a, &b| data[a].partial_cmp(&data[b]).unwrap());
        for w in idx.windows(2) {
            assert!(q[w[0]] <= q[w[1]]);
        }
    }

    #[test]
    fn degenerate_constant() {
        let data = vec![0.42f32; 64];
        let p = QuantParams::from_data(&data, K);
        assert!(p.is_degenerate());
        assert!(quantize(&data, &p).iter().all(|&v| v == 0));
    }

    #[test]
    fn empty() {
        let p = QuantParams::from_data(&[], K);
        assert!(quantize(&[], &p).is_empty());
    }

    #[test]
    fn k8_vs_k16_consistent_buckets() {
        let data = tensor(3, 512);
        let p8 = QuantParams { k: 8, ..QuantParams::from_data(&data, 8) };
        let p16 = QuantParams::from_data(&data, K);
        let q8 = quantize(&data, &p8);
        let q16 = quantize(&data, &p16);
        // 16-bit codes truncated to 8 bits differ from direct 8-bit codes
        // by at most 1 (eps differs in the last digit only).
        for (a, b) in q8.iter().zip(&q16) {
            let t = b >> 8;
            assert!((*a as i64 - t as i64).abs() <= 1, "{a} vs {t}");
        }
    }
}
