//! `.pnet` — the progressive model container / wire format.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [magic "PNET"][version u16][flags u16]
//! [manifest_len u32][manifest JSON bytes]      // model + tensor + schedule metadata
//! fragment*                                    // stage-major order
//!
//! fragment := [stage u8][pad u8][tensor u16][len u32][crc32 u32][payload]
//! ```
//!
//! Fragments are ordered **stage-major** (stage 0 of every tensor first),
//! so a client holding any byte prefix that covers the first `m` stages
//! can reconstruct the m-th approximate model — the property progressive
//! transmission needs. Each fragment carries a CRC32 so corruption is
//! detected per-fragment, not per-file. The container adds only
//! `16 B × stages × tensors` of framing plus one manifest — the payload
//! itself is exactly the singleton quantized size (paper §III-B: no model
//! size inflation).

#![forbid(unsafe_code)]

pub mod header;
pub mod reader;
pub mod writer;

pub use header::{
    FragmentHeader, PnetManifest, StageIndex, TensorMeta, FRAG_HEADER_LEN, MAGIC, VERSION,
};
pub use reader::{FrameParser, ParserEvent, PnetReader};
pub use writer::PnetWriter;
