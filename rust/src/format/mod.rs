//! `.pnet` — the progressive model container / wire format.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [magic "PNET"][version u16][flags u16]
//! [manifest_len u32][manifest JSON bytes]      // model + tensor + schedule metadata
//! fragment*                                    // stage-major order
//!
//! fragment := [stage u8][pad u8][tensor u16][len u32][crc32 u32][payload]
//! ```
//!
//! Fragments are ordered **stage-major** (stage 0 of every tensor first),
//! so a client holding any byte prefix that covers the first `m` stages
//! can reconstruct the m-th approximate model — the property progressive
//! transmission needs. Each fragment carries a CRC32 so corruption is
//! detected per-fragment, not per-file. The container adds only
//! `16 B × stages × tensors` of framing plus one manifest — the payload
//! itself is exactly the singleton quantized size (paper §III-B: no model
//! size inflation).
//!
//! **Layer-granular ordering (`LayerMajor`).** A manifest may carry a
//! `layers` annotation (tensors-per-layer counts, see
//! [`header::infer_layer_groups`]). Within each stage, a layer's frames
//! then form a contiguous run whose boundary the [`StageIndex`] exposes
//! (`layer_span`), letting clients emit per-layer readiness events and
//! start executing layer 0 while later layers of the same stage are
//! still in flight. The fragment wire order is unchanged — tensors are
//! already laid out layer by layer — so the body is byte-identical to an
//! unannotated container and v1 readers simply ignore the extra manifest
//! key.

#![forbid(unsafe_code)]

pub mod header;
pub mod reader;
pub mod writer;

pub use header::{
    infer_layer_groups, FragmentHeader, PnetManifest, StageIndex, TensorMeta, FRAG_HEADER_LEN,
    MAGIC, VERSION,
};
pub use reader::{validated_prefix, FrameParser, ParserEvent, PnetReader};
pub use writer::PnetWriter;
