//! `.pnet` decoding: a whole-file reader and an **incremental** frame
//! parser that consumes arbitrary byte chunks as they arrive from the
//! network — the entry point of the progressive client pipeline.

#![forbid(unsafe_code)]

use anyhow::{bail, Result};

use super::header::{FragmentHeader, PnetManifest, FRAG_HEADER_LEN, MAGIC, VERSION};
use crate::util::json::Json;

/// Events produced by the incremental parser.
#[derive(Debug, Clone, PartialEq)]
pub enum ParserEvent {
    /// The manifest is fully parsed (fires exactly once, first).
    Manifest(Box<PnetManifest>),
    /// A fragment's payload passed CRC and is ready to absorb.
    Fragment {
        stage: usize,
        tensor: usize,
        payload: Vec<u8>,
    },
}

#[derive(Debug)]
enum State {
    Preamble,
    Manifest { need: usize },
    FrameHeader,
    Payload { header: FragmentHeader },
    Done,
}

/// Incremental `.pnet` stream parser. Feed it chunks; collect events.
///
/// A parser covers a *stage window* `[start_stage, end_stage)` of the
/// container. The default ([`FrameParser::new`]) covers everything:
/// preamble + all frames. [`FrameParser::for_stage_prefix`] parses a
/// stream that stops after stage `end` (a stage-range fetch from 0), and
/// [`FrameParser::resume`] parses a frames-only stream that starts at a
/// later stage boundary, with the manifest supplied up front.
pub struct FrameParser {
    buf: Vec<u8>,
    state: State,
    manifest: Option<PnetManifest>,
    frames_seen: usize,
    total_frames: usize,
    bytes_consumed: u64,
    start_stage: usize,
    /// exclusive end of the stage window; None = through the last stage
    end_stage: Option<usize>,
}

impl Default for FrameParser {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameParser {
    pub fn new() -> Self {
        Self {
            buf: Vec::new(),
            state: State::Preamble,
            manifest: None,
            frames_seen: 0,
            total_frames: 0,
            bytes_consumed: 0,
            start_stage: 0,
            end_stage: None,
        }
    }

    /// Parser for a stream that carries the preamble plus only stages
    /// `[0, end)` — the body of a `stages: 0..end` fetch.
    pub fn for_stage_prefix(end: usize) -> Self {
        let mut p = Self::new();
        p.end_stage = Some(end);
        p
    }

    /// Parser resuming at a stage boundary: the stream carries only the
    /// frames of stages `[start, end)` (no preamble — the caller already
    /// holds the manifest from the interrupted fetch).
    pub fn resume(manifest: PnetManifest, start: usize, end: Option<usize>) -> Result<Self> {
        let stages = manifest.schedule.stages();
        let end = end.unwrap_or(stages);
        if start >= end || end > stages {
            bail!("invalid resume window [{start}, {end}) for {stages}-stage container");
        }
        let total_frames = (end - start) * manifest.tensors.len();
        Ok(Self {
            buf: Vec::new(),
            state: State::FrameHeader,
            manifest: Some(manifest),
            frames_seen: 0,
            total_frames,
            bytes_consumed: 0,
            start_stage: start,
            end_stage: Some(end),
        })
    }

    /// Reuse a finished parser for another frames-only stage window of the
    /// same container. Keeps the manifest — callers fetching many stage
    /// ranges (the multiplex client) avoid cloning it per request.
    pub fn rewindow(&mut self, start: usize, end: usize) -> Result<()> {
        let m = self
            .manifest
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no manifest to reuse"))?;
        let stages = m.schedule.stages();
        if start >= end || end > stages {
            bail!("invalid resume window [{start}, {end}) for {stages}-stage container");
        }
        if !self.buf.is_empty() {
            bail!("{} unparsed bytes left from the previous window", self.buf.len());
        }
        self.total_frames = (end - start) * m.tensors.len();
        self.frames_seen = 0;
        self.bytes_consumed = 0;
        self.start_stage = start;
        self.end_stage = Some(end);
        self.state = State::FrameHeader;
        Ok(())
    }

    pub fn manifest(&self) -> Option<&PnetManifest> {
        self.manifest.as_ref()
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, State::Done)
    }

    pub fn bytes_consumed(&self) -> u64 {
        self.bytes_consumed
    }

    /// Highest stage boundary fully parsed so far, as an absolute stage
    /// count: a return of `s` means stages `[start_stage, s)` of this
    /// stream's window arrived completely. Used to pick where a
    /// disconnected fetch should resume.
    pub fn stage_boundary(&self) -> usize {
        match &self.manifest {
            Some(m) if !m.tensors.is_empty() => {
                self.start_stage + self.frames_seen / m.tensors.len()
            }
            _ => self.start_stage,
        }
    }

    /// Feed a chunk; returns all events that completed.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<Vec<ParserEvent>> {
        self.buf.extend_from_slice(chunk);
        self.bytes_consumed += chunk.len() as u64;
        let mut events = Vec::new();
        loop {
            match &self.state {
                State::Preamble => {
                    if self.buf.len() < 12 {
                        break;
                    }
                    if &self.buf[..4] != MAGIC {
                        bail!("bad magic {:02x?}", &self.buf[..4]);
                    }
                    let version = u16::from_le_bytes([self.buf[4], self.buf[5]]);
                    if version != VERSION {
                        bail!("unsupported version {version}");
                    }
                    let mlen = u32::from_le_bytes([
                        self.buf[8],
                        self.buf[9],
                        self.buf[10],
                        self.buf[11],
                    ]) as usize;
                    if mlen > 64 << 20 {
                        bail!("manifest absurdly large: {mlen}");
                    }
                    self.buf.drain(..12);
                    self.state = State::Manifest { need: mlen };
                }
                State::Manifest { need } => {
                    let need = *need;
                    if self.buf.len() < need {
                        break;
                    }
                    let text = std::str::from_utf8(&self.buf[..need])?;
                    let manifest = PnetManifest::from_json(&Json::parse(text)?)?;
                    self.buf.drain(..need);
                    let stages = manifest.schedule.stages();
                    let end = match self.end_stage {
                        None => stages,
                        Some(e) if e >= 1 && e <= stages => e,
                        Some(e) => bail!("stage window end {e} invalid for {stages} stages"),
                    };
                    self.end_stage = Some(end);
                    self.total_frames = (end - self.start_stage) * manifest.tensors.len();
                    events.push(ParserEvent::Manifest(Box::new(manifest.clone())));
                    self.manifest = Some(manifest);
                    self.state = State::FrameHeader;
                }
                State::FrameHeader => {
                    if self.frames_seen == self.total_frames {
                        self.state = State::Done;
                        continue;
                    }
                    if self.buf.len() < FRAG_HEADER_LEN {
                        break;
                    }
                    let header = FragmentHeader::decode(&self.buf[..FRAG_HEADER_LEN])?;
                    let m = self.manifest.as_ref().unwrap();
                    let end = self.end_stage.unwrap_or_else(|| m.schedule.stages());
                    if (header.stage as usize) < self.start_stage
                        || header.stage as usize >= end
                    {
                        bail!(
                            "fragment stage {} outside window [{}, {end})",
                            header.stage,
                            self.start_stage
                        );
                    }
                    if header.tensor as usize >= m.tensors.len() {
                        bail!("fragment tensor {} out of range", header.tensor);
                    }
                    let expect =
                        m.schedule.plane_bytes(header.stage as usize, m.tensors[header.tensor as usize].numel);
                    if header.len as usize != expect {
                        bail!(
                            "fragment ({}, {}) declares {} bytes, manifest expects {expect}",
                            header.stage,
                            header.tensor,
                            header.len
                        );
                    }
                    self.buf.drain(..FRAG_HEADER_LEN);
                    self.state = State::Payload { header };
                }
                State::Payload { header } => {
                    let need = header.len as usize;
                    if self.buf.len() < need {
                        break;
                    }
                    let payload: Vec<u8> = self.buf.drain(..need).collect();
                    let crc = crate::util::crc32::hash(&payload);
                    if crc != header.crc32 {
                        bail!(
                            "fragment ({}, {}) CRC mismatch: {:08x} != {:08x}",
                            header.stage,
                            header.tensor,
                            crc,
                            header.crc32
                        );
                    }
                    events.push(ParserEvent::Fragment {
                        stage: header.stage as usize,
                        tensor: header.tensor as usize,
                        payload,
                    });
                    self.frames_seen += 1;
                    self.state = State::FrameHeader;
                }
                State::Done => {
                    if !self.buf.is_empty() {
                        bail!("{} trailing bytes after final fragment", self.buf.len());
                    }
                    break;
                }
            }
        }
        Ok(events)
    }
}

/// Largest trustworthy prefix of a (possibly damaged) container byte
/// stream, as `(valid_len, complete_stages)`.
///
/// Feeds the bytes through a fresh [`FrameParser`] and stops at the first
/// parse/CRC failure or mid-frame truncation, then rounds *down* to the
/// last complete stage boundary — the only resume points the wire
/// protocol offers. A stream whose preamble doesn't parse is worth
/// nothing (`(0, 0)`); one with a valid manifest but no complete stage is
/// worth only the preamble. Used by `client::cache` to sanitize partial
/// cache files before resuming and by `fleet::edge` to validate fills.
pub fn validated_prefix(bytes: &[u8]) -> (usize, usize) {
    let mut parser = FrameParser::new();
    // an Err mid-feed leaves everything parsed *before* the failure
    // counted in the parser state, which is exactly what we want
    let _ = parser.feed(bytes);
    let Some(manifest) = parser.manifest() else {
        return (0, 0);
    };
    let stages = parser.stage_boundary();
    let index = super::header::StageIndex::from_manifest(manifest);
    let valid_len = if stages > 0 {
        match index.body_range(Some((0, stages as u32))) {
            Ok(r) => r.end,
            Err(_) => return (0, 0),
        }
    } else {
        index.preamble_len()
    };
    // never claim more than we were given (body_range is manifest-derived;
    // a truncated final stage must not round up past the actual bytes)
    if valid_len > bytes.len() {
        (0, 0)
    } else {
        (valid_len, stages)
    }
}

/// Whole-file reader (validates everything eagerly).
pub struct PnetReader {
    pub manifest: PnetManifest,
    /// `fragments[stage][tensor]`
    pub fragments: Vec<Vec<Vec<u8>>>,
}

impl PnetReader {
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut parser = FrameParser::new();
        let events = parser.feed(bytes)?;
        if !parser.is_done() {
            bail!("truncated .pnet: consumed {} bytes", parser.bytes_consumed());
        }
        let mut manifest = None;
        let mut fragments: Vec<Vec<Vec<u8>>> = Vec::new();
        for ev in events {
            match ev {
                ParserEvent::Manifest(m) => {
                    fragments =
                        vec![vec![Vec::new(); m.tensors.len()]; m.schedule.stages()];
                    manifest = Some(*m);
                }
                ParserEvent::Fragment {
                    stage,
                    tensor,
                    payload,
                } => {
                    fragments[stage][tensor] = payload;
                }
            }
        }
        let manifest = manifest.ok_or_else(|| anyhow::anyhow!("no manifest"))?;
        Ok(Self {
            manifest,
            fragments,
        })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_bytes(&std::fs::read(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::header::manifest_from_weights;
    use crate::format::writer::PnetWriter;
    use crate::quant::Schedule;
    use crate::util::rng::Rng;

    fn sample_bytes() -> (PnetWriter, Vec<u8>) {
        let mut r = Rng::new(7);
        let flat: Vec<f32> = (0..500).map(|_| r.normal() as f32).collect();
        let m = manifest_from_weights(
            "toy",
            "classify",
            &[("a".to_string(), vec![400]), ("b".to_string(), vec![100])],
            &flat,
            Schedule::paper_default(),
        )
        .unwrap();
        let w = PnetWriter::encode(m, &flat).unwrap();
        let bytes = w.to_bytes();
        (w, bytes)
    }

    #[test]
    fn whole_file_roundtrip() {
        let (w, bytes) = sample_bytes();
        let r = PnetReader::from_bytes(&bytes).unwrap();
        assert_eq!(&r.manifest, w.manifest());
        for s in 0..8 {
            for t in 0..2 {
                assert_eq!(r.fragments[s][t], w.fragment(s, t));
            }
        }
    }

    #[test]
    fn incremental_byte_by_byte() {
        let (_, bytes) = sample_bytes();
        let mut parser = FrameParser::new();
        let mut frags = 0;
        let mut got_manifest = false;
        for b in bytes {
            for ev in parser.feed(&[b]).unwrap() {
                match ev {
                    ParserEvent::Manifest(_) => got_manifest = true,
                    ParserEvent::Fragment { .. } => frags += 1,
                }
            }
        }
        assert!(got_manifest);
        assert_eq!(frags, 16);
        assert!(parser.is_done());
    }

    #[test]
    fn stage_major_ordering() {
        let (_, bytes) = sample_bytes();
        let mut parser = FrameParser::new();
        let mut order = Vec::new();
        for chunk in bytes.chunks(97) {
            for ev in parser.feed(chunk).unwrap() {
                if let ParserEvent::Fragment { stage, tensor, .. } = ev {
                    order.push((stage, tensor));
                }
            }
        }
        let expect: Vec<(usize, usize)> =
            (0..8).flat_map(|s| (0..2).map(move |t| (s, t))).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn stage_prefix_then_resume_covers_all_fragments() {
        let (w, bytes) = sample_bytes();
        let idx = w.stage_index();
        let split = idx.stage_span(0, 3).unwrap().end;

        // prefix stream: preamble + stages [0, 3)
        let mut p1 = FrameParser::for_stage_prefix(3);
        let ev1 = p1.feed(&bytes[..split]).unwrap();
        assert!(p1.is_done(), "prefix parser must finish at the window end");
        assert_eq!(p1.stage_boundary(), 3);
        let mut order = Vec::new();
        for ev in &ev1 {
            if let ParserEvent::Fragment { stage, tensor, .. } = ev {
                order.push((*stage, *tensor));
            }
        }
        assert_eq!(order.len(), 3 * 2);

        // resume stream: frames only, stages [3, 8)
        let manifest = p1.manifest().unwrap().clone();
        let mut p2 = FrameParser::resume(manifest, 3, None).unwrap();
        assert_eq!(p2.stage_boundary(), 3);
        let ev2 = p2.feed(&bytes[split..]).unwrap();
        assert!(p2.is_done());
        assert_eq!(p2.stage_boundary(), 8);
        for ev in &ev2 {
            if let ParserEvent::Fragment { stage, tensor, .. } = ev {
                order.push((*stage, *tensor));
            }
        }
        let expect: Vec<(usize, usize)> =
            (0..8).flat_map(|s| (0..2).map(move |t| (s, t))).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn rewindow_reuses_parser_across_ranges() {
        let (w, bytes) = sample_bytes();
        let idx = w.stage_index();
        let mut p = FrameParser::for_stage_prefix(1);
        let ev0 = p.feed(&bytes[..idx.stage_span(0, 1).unwrap().end]).unwrap();
        assert!(p.is_done());
        let mut frags = ev0
            .iter()
            .filter(|e| matches!(e, ParserEvent::Fragment { .. }))
            .count();
        // walk the rest one stage at a time on the same parser
        for s in 1..8 {
            p.rewindow(s, s + 1).unwrap();
            assert!(!p.is_done());
            let ev = p.feed(&bytes[idx.stage_span(s, s + 1).unwrap()]).unwrap();
            assert!(p.is_done(), "stage {s}");
            assert_eq!(p.stage_boundary(), s + 1);
            frags += ev.len();
        }
        assert_eq!(frags, 16);
        // a parser with leftover bytes refuses to rewindow
        let mut q = FrameParser::for_stage_prefix(1);
        let half = idx.stage_span(0, 1).unwrap().end / 2;
        q.feed(&bytes[..half]).unwrap();
        assert!(q.rewindow(1, 2).is_err());
    }

    #[test]
    fn resume_window_validation() {
        let (w, _) = sample_bytes();
        let m = w.manifest().clone();
        assert!(FrameParser::resume(m.clone(), 8, None).is_err());
        assert!(FrameParser::resume(m.clone(), 3, Some(3)).is_err());
        assert!(FrameParser::resume(m.clone(), 0, Some(9)).is_err());
        assert!(FrameParser::resume(m, 2, Some(5)).is_ok());
    }

    #[test]
    fn out_of_window_fragment_rejected() {
        let (w, bytes) = sample_bytes();
        let idx = w.stage_index();
        // a parser resumed at stage 3 must reject stage-0 frames
        let mut p = FrameParser::resume(w.manifest().clone(), 3, None).unwrap();
        let stage0 = &bytes[idx.stage_span(0, 1).unwrap()];
        assert!(p.feed(stage0).is_err());
    }

    #[test]
    fn validated_prefix_full_container() {
        let (w, bytes) = sample_bytes();
        let (len, stages) = validated_prefix(&bytes);
        assert_eq!(len, bytes.len());
        assert_eq!(stages, w.manifest().schedule.stages());
    }

    #[test]
    fn validated_prefix_rounds_down_to_stage_boundary() {
        let (w, bytes) = sample_bytes();
        let idx = w.stage_index();
        let b3 = idx.stage_span(0, 3).unwrap().end;
        // truncate mid-way through stage 3: only stages [0, 3) are usable
        let cut = b3 + 5;
        let (len, stages) = validated_prefix(&bytes[..cut]);
        assert_eq!(stages, 3);
        assert_eq!(len, b3);
    }

    #[test]
    fn validated_prefix_stops_at_crc_damage() {
        let (w, mut bytes) = sample_bytes();
        let idx = w.stage_index();
        let b2 = idx.stage_span(0, 2).unwrap().end;
        // flip a payload byte inside stage 2: stages [0, 2) stay valid
        bytes[b2 + idx.stage_span(2, 3).unwrap().len() / 2] ^= 0xFF;
        let (len, stages) = validated_prefix(&bytes);
        assert_eq!(stages, 2);
        assert_eq!(len, b2);
    }

    #[test]
    fn validated_prefix_worthless_without_manifest() {
        let (_, mut bytes) = sample_bytes();
        bytes[0] = b'X';
        assert_eq!(validated_prefix(&bytes), (0, 0));
        assert_eq!(validated_prefix(&[]), (0, 0));
        assert_eq!(validated_prefix(&bytes[..6]), (0, 0));
    }

    #[test]
    fn validated_prefix_preamble_only() {
        let (w, bytes) = sample_bytes();
        let pre = w.stage_index().preamble_len();
        // a few bytes into stage 0 but no complete stage yet
        let (len, stages) = validated_prefix(&bytes[..pre + 3]);
        assert_eq!(stages, 0);
        assert_eq!(len, pre);
    }

    #[test]
    fn corruption_detected() {
        let (_, mut bytes) = sample_bytes();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF; // flip payload byte of last fragment
        let mut parser = FrameParser::new();
        let mut failed = false;
        for chunk in bytes.chunks(64) {
            if parser.feed(chunk).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "corrupted payload must fail CRC");
    }

    #[test]
    fn bad_magic_rejected() {
        let (_, mut bytes) = sample_bytes();
        bytes[0] = b'X';
        assert!(PnetReader::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let (_, bytes) = sample_bytes();
        assert!(PnetReader::from_bytes(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let (_, mut bytes) = sample_bytes();
        bytes.push(0);
        assert!(PnetReader::from_bytes(&bytes).is_err());
    }
}
