//! `.pnet` header and manifest structures.

#![forbid(unsafe_code)]

use std::ops::Range;

use anyhow::{bail, Result};

use crate::quant::{QuantParams, Schedule, K};
use crate::util::json::{self, Json};

pub const MAGIC: &[u8; 4] = b"PNET";
pub const VERSION: u16 = 1;
/// stage u8 + pad u8 + tensor u16 + len u32 + crc u32 = 12 bytes
pub const FRAG_HEADER_LEN: usize = 12;

/// Per-tensor metadata carried in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub offset: usize,
    pub min: f32,
    pub max: f32,
}

impl TensorMeta {
    pub fn quant_params(&self, k: u32) -> QuantParams {
        QuantParams {
            min: self.min,
            max: self.max,
            k,
        }
    }
}

/// The `.pnet` manifest: everything a client needs to reconstruct.
#[derive(Debug, Clone, PartialEq)]
pub struct PnetManifest {
    pub model: String,
    pub task: String,
    pub k: u32,
    pub schedule: Schedule,
    pub tensors: Vec<TensorMeta>,
}

impl PnetManifest {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel).sum()
    }

    /// Total payload bytes (all fragments, without framing).
    pub fn payload_bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| self.schedule.total_bytes(t.numel))
            .sum()
    }

    /// Payload bytes of one stage across all tensors.
    pub fn stage_payload_bytes(&self, stage: usize) -> usize {
        self.tensors
            .iter()
            .map(|t| self.schedule.plane_bytes(stage, t.numel))
            .sum()
    }

    /// Wire bytes including framing and manifest.
    pub fn wire_bytes(&self) -> usize {
        let frames = self.schedule.stages() * self.tensors.len() * FRAG_HEADER_LEN;
        8 + 4 + self.to_json().to_string().len() + frames + self.payload_bytes()
    }

    /// Byte-range index of the container this manifest describes.
    pub fn stage_index(&self) -> StageIndex {
        StageIndex::from_manifest(self)
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("task", json::s(&self.task)),
            ("k", json::num(self.k as f64)),
            (
                "schedule",
                json::arr(
                    self.schedule
                        .widths()
                        .iter()
                        .map(|&w| json::num(w as f64))
                        .collect(),
                ),
            ),
            (
                "tensors",
                json::arr(
                    self.tensors
                        .iter()
                        .map(|t| {
                            json::obj(vec![
                                ("name", json::s(&t.name)),
                                (
                                    "shape",
                                    json::arr(
                                        t.shape.iter().map(|&d| json::num(d as f64)).collect(),
                                    ),
                                ),
                                ("numel", json::num(t.numel as f64)),
                                ("offset", json::num(t.offset as f64)),
                                ("min", json::num(t.min as f64)),
                                ("max", json::num(t.max as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let k = j.get("k")?.as_i64()? as u32;
        if k == 0 || k > 32 {
            bail!("invalid k={k}");
        }
        let widths = j
            .get("schedule")?
            .as_arr()?
            .iter()
            .map(|w| Ok(w.as_i64()? as u32))
            .collect::<Result<Vec<_>>>()?;
        let schedule = Schedule::new(widths, k)?;
        let mut tensors = Vec::new();
        for t in j.get("tensors")?.as_arr()? {
            let shape = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            let numel = t.get("numel")?.as_usize()?;
            if shape.iter().product::<usize>() != numel {
                bail!("tensor {}: shape/numel mismatch", t.get("name")?.as_str()?);
            }
            tensors.push(TensorMeta {
                name: t.get("name")?.as_str()?.to_string(),
                shape,
                numel,
                offset: t.get("offset")?.as_usize()?,
                min: t.get("min")?.as_f64()? as f32,
                max: t.get("max")?.as_f64()? as f32,
            });
        }
        if tensors.is_empty() {
            bail!("manifest has no tensors");
        }
        // offsets must be contiguous
        let mut off = 0;
        for t in &tensors {
            if t.offset != off {
                bail!("tensor {} offset {} != expected {off}", t.name, t.offset);
            }
            off += t.numel;
        }
        Ok(Self {
            model: j.get("model")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            k,
            schedule,
            tensors,
        })
    }
}

/// Derived byte-range index of a stage-major `.pnet` container: where the
/// preamble ends and where every (stage, tensor) frame lives.
///
/// The index is fully determined by the manifest — the JSON serialization
/// is deterministic and the frame layout is fixed — so it costs no wire
/// bytes: the server computes it once per encoding to answer stage-range
/// requests with borrowed slices, and a client can compute it from the
/// manifest to know exactly which byte every stage starts at.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageIndex {
    preamble_len: usize,
    /// absolute start of each stage's first frame; one extra final entry
    /// equals the container's total length
    stage_starts: Vec<usize>,
    /// `frame_starts[stage][tensor]`: absolute start of the frame header
    frame_starts: Vec<Vec<usize>>,
    /// `payload_lens[stage][tensor]`: packed plane bytes of that fragment
    payload_lens: Vec<Vec<usize>>,
}

impl StageIndex {
    /// Compute the index for a container encoded from `manifest`.
    pub fn from_manifest(manifest: &PnetManifest) -> Self {
        let preamble_len = 12 + manifest.to_json().to_string().len();
        let stages = manifest.schedule.stages();
        let mut stage_starts = Vec::with_capacity(stages + 1);
        let mut frame_starts = Vec::with_capacity(stages);
        let mut payload_lens = Vec::with_capacity(stages);
        let mut off = preamble_len;
        for s in 0..stages {
            stage_starts.push(off);
            let mut fs = Vec::with_capacity(manifest.tensors.len());
            let mut pl = Vec::with_capacity(manifest.tensors.len());
            for t in &manifest.tensors {
                fs.push(off);
                let plen = manifest.schedule.plane_bytes(s, t.numel);
                pl.push(plen);
                off += FRAG_HEADER_LEN + plen;
            }
            frame_starts.push(fs);
            payload_lens.push(pl);
        }
        stage_starts.push(off);
        Self {
            preamble_len,
            stage_starts,
            frame_starts,
            payload_lens,
        }
    }

    pub fn stages(&self) -> usize {
        self.frame_starts.len()
    }

    pub fn tensors(&self) -> usize {
        self.frame_starts.first().map_or(0, |fs| fs.len())
    }

    /// Bytes of the preamble (magic + version + flags + manifest).
    pub fn preamble_len(&self) -> usize {
        self.preamble_len
    }

    /// Total container length in bytes.
    pub fn total_len(&self) -> usize {
        *self.stage_starts.last().expect("stage_starts never empty")
    }

    /// One frame (header + payload) of a (stage, tensor) fragment.
    pub fn frame_range(&self, stage: usize, tensor: usize) -> Range<usize> {
        let start = self.frame_starts[stage][tensor];
        start..start + FRAG_HEADER_LEN + self.payload_lens[stage][tensor]
    }

    /// Payload bytes (without the frame header) of a (stage, tensor) fragment.
    pub fn payload_range(&self, stage: usize, tensor: usize) -> Range<usize> {
        let r = self.frame_range(stage, tensor);
        r.start + FRAG_HEADER_LEN..r.end
    }

    /// Frames of stages `[a, b)` — contiguous because the container is
    /// stage-major.
    pub fn stage_span(&self, a: usize, b: usize) -> Result<Range<usize>> {
        if a >= b || b > self.stages() {
            bail!(
                "invalid stage range [{a}, {b}) for {}-stage container",
                self.stages()
            );
        }
        Ok(self.stage_starts[a]..self.stage_starts[b])
    }

    /// Response body for a stage-range request: preamble + frames when the
    /// range starts at stage 0 (fresh fetch needs the manifest), frames
    /// only otherwise (a resuming client already holds the manifest).
    pub fn body_range(&self, stages: Option<(u32, u32)>) -> Result<Range<usize>> {
        match stages {
            None => Ok(0..self.total_len()),
            Some((a, b)) => {
                let span = self.stage_span(a as usize, b as usize)?;
                Ok(if a == 0 { 0..span.end } else { span })
            }
        }
    }
}

/// One fragment's frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragmentHeader {
    pub stage: u8,
    pub tensor: u16,
    pub len: u32,
    pub crc32: u32,
}

impl FragmentHeader {
    pub fn encode(&self) -> [u8; FRAG_HEADER_LEN] {
        let mut out = [0u8; FRAG_HEADER_LEN];
        out[0] = self.stage;
        out[1] = 0; // pad
        out[2..4].copy_from_slice(&self.tensor.to_le_bytes());
        out[4..8].copy_from_slice(&self.len.to_le_bytes());
        out[8..12].copy_from_slice(&self.crc32.to_le_bytes());
        out
    }

    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() < FRAG_HEADER_LEN {
            bail!("fragment header truncated");
        }
        Ok(Self {
            stage: b[0],
            tensor: u16::from_le_bytes([b[2], b[3]]),
            len: u32::from_le_bytes([b[4], b[5], b[6], b[7]]),
            crc32: u32::from_le_bytes([b[8], b[9], b[10], b[11]]),
        })
    }
}

/// Helper: build a manifest from raw weights + a schedule (encoder side).
pub fn manifest_from_weights(
    model: &str,
    task: &str,
    tensors: &[(String, Vec<usize>)],
    flat: &[f32],
    schedule: Schedule,
) -> Result<PnetManifest> {
    let mut metas = Vec::new();
    let mut off = 0;
    for (name, shape) in tensors {
        let numel: usize = shape.iter().product();
        if off + numel > flat.len() {
            bail!("weights too short for tensor {name}");
        }
        let qp = QuantParams::from_data(&flat[off..off + numel], K);
        metas.push(TensorMeta {
            name: name.clone(),
            shape: shape.clone(),
            numel,
            offset: off,
            min: qp.min,
            max: qp.max,
        });
        off += numel;
    }
    if off != flat.len() {
        bail!("weights length {} != manifest total {off}", flat.len());
    }
    Ok(PnetManifest {
        model: model.to_string(),
        task: task.to_string(),
        k: K,
        schedule,
        tensors: metas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> PnetManifest {
        manifest_from_weights(
            "m",
            "classify",
            &[
                ("a.w".to_string(), vec![4, 8]),
                ("a.b".to_string(), vec![8]),
            ],
            &(0..40).map(|i| i as f32 * 0.1).collect::<Vec<_>>(),
            Schedule::paper_default(),
        )
        .unwrap()
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = sample_manifest();
        let j = m.to_json();
        let m2 = PnetManifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn fragment_header_roundtrip() {
        let h = FragmentHeader {
            stage: 3,
            tensor: 517,
            len: 123_456,
            crc32: 0xDEADBEEF,
        };
        assert_eq!(FragmentHeader::decode(&h.encode()).unwrap(), h);
    }

    #[test]
    fn payload_accounting() {
        let m = sample_manifest();
        assert_eq!(m.param_count(), 40);
        // 16 bits over 40 elements = 80 bytes total payload
        assert_eq!(m.payload_bytes(), 80);
        let per_stage: usize = (0..8).map(|s| m.stage_payload_bytes(s)).sum();
        assert_eq!(per_stage, m.payload_bytes());
    }

    #[test]
    fn stage_index_accounting() {
        let m = sample_manifest();
        let idx = m.stage_index();
        assert_eq!(idx.stages(), 8);
        assert_eq!(idx.tensors(), 2);
        assert_eq!(idx.total_len(), m.wire_bytes());
        assert_eq!(idx.preamble_len(), 12 + m.to_json().to_string().len());
        // frames tile the body contiguously, stage-major
        let mut off = idx.preamble_len();
        for s in 0..idx.stages() {
            assert_eq!(idx.stage_span(s, s + 1).unwrap().start, off);
            for t in 0..idx.tensors() {
                let fr = idx.frame_range(s, t);
                assert_eq!(fr.start, off);
                let pr = idx.payload_range(s, t);
                assert_eq!(pr.start, fr.start + FRAG_HEADER_LEN);
                assert_eq!(pr.end, fr.end);
                assert_eq!(pr.len(), m.schedule.plane_bytes(s, m.tensors[t].numel));
                off = fr.end;
            }
            assert_eq!(idx.stage_span(s, s + 1).unwrap().end, off);
        }
        assert_eq!(off, idx.total_len());
        // spans concatenate
        let whole = idx.stage_span(0, 8).unwrap();
        assert_eq!(whole.end, idx.total_len());
        assert!(idx.stage_span(3, 3).is_err());
        assert!(idx.stage_span(0, 9).is_err());
    }

    #[test]
    fn body_range_semantics() {
        let m = sample_manifest();
        let idx = m.stage_index();
        // full fetch = whole container
        assert_eq!(idx.body_range(None).unwrap(), 0..idx.total_len());
        // range from stage 0 includes the preamble
        let r0 = idx.body_range(Some((0, 2))).unwrap();
        assert_eq!(r0.start, 0);
        assert_eq!(r0.end, idx.stage_span(0, 2).unwrap().end);
        // later ranges are frames only
        let r1 = idx.body_range(Some((2, 5))).unwrap();
        assert_eq!(r1, idx.stage_span(2, 5).unwrap());
        assert!(idx.body_range(Some((5, 5))).is_err());
        assert!(idx.body_range(Some((0, 99))).is_err());
    }

    #[test]
    fn bad_manifests_rejected() {
        let m = sample_manifest();
        let mut j = m.to_json().to_string();
        j = j.replace("\"numel\":32", "\"numel\":31");
        assert!(PnetManifest::from_json(&Json::parse(&j).unwrap()).is_err());
    }

    #[test]
    fn weights_length_mismatch_rejected() {
        let r = manifest_from_weights(
            "m",
            "classify",
            &[("a".to_string(), vec![10])],
            &[0.0; 9],
            Schedule::paper_default(),
        );
        assert!(r.is_err());
    }
}
