//! `.pnet` header and manifest structures.

use anyhow::{bail, Result};

use crate::quant::{QuantParams, Schedule, K};
use crate::util::json::{self, Json};

pub const MAGIC: &[u8; 4] = b"PNET";
pub const VERSION: u16 = 1;
/// stage u8 + pad u8 + tensor u16 + len u32 + crc u32 = 12 bytes
pub const FRAG_HEADER_LEN: usize = 12;

/// Per-tensor metadata carried in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub offset: usize,
    pub min: f32,
    pub max: f32,
}

impl TensorMeta {
    pub fn quant_params(&self, k: u32) -> QuantParams {
        QuantParams {
            min: self.min,
            max: self.max,
            k,
        }
    }
}

/// The `.pnet` manifest: everything a client needs to reconstruct.
#[derive(Debug, Clone, PartialEq)]
pub struct PnetManifest {
    pub model: String,
    pub task: String,
    pub k: u32,
    pub schedule: Schedule,
    pub tensors: Vec<TensorMeta>,
}

impl PnetManifest {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel).sum()
    }

    /// Total payload bytes (all fragments, without framing).
    pub fn payload_bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| self.schedule.total_bytes(t.numel))
            .sum()
    }

    /// Payload bytes of one stage across all tensors.
    pub fn stage_payload_bytes(&self, stage: usize) -> usize {
        self.tensors
            .iter()
            .map(|t| self.schedule.plane_bytes(stage, t.numel))
            .sum()
    }

    /// Wire bytes including framing and manifest.
    pub fn wire_bytes(&self) -> usize {
        let frames = self.schedule.stages() * self.tensors.len() * FRAG_HEADER_LEN;
        8 + 4 + self.to_json().to_string().len() + frames + self.payload_bytes()
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("task", json::s(&self.task)),
            ("k", json::num(self.k as f64)),
            (
                "schedule",
                json::arr(
                    self.schedule
                        .widths()
                        .iter()
                        .map(|&w| json::num(w as f64))
                        .collect(),
                ),
            ),
            (
                "tensors",
                json::arr(
                    self.tensors
                        .iter()
                        .map(|t| {
                            json::obj(vec![
                                ("name", json::s(&t.name)),
                                (
                                    "shape",
                                    json::arr(
                                        t.shape.iter().map(|&d| json::num(d as f64)).collect(),
                                    ),
                                ),
                                ("numel", json::num(t.numel as f64)),
                                ("offset", json::num(t.offset as f64)),
                                ("min", json::num(t.min as f64)),
                                ("max", json::num(t.max as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let k = j.get("k")?.as_i64()? as u32;
        if k == 0 || k > 32 {
            bail!("invalid k={k}");
        }
        let widths = j
            .get("schedule")?
            .as_arr()?
            .iter()
            .map(|w| Ok(w.as_i64()? as u32))
            .collect::<Result<Vec<_>>>()?;
        let schedule = Schedule::new(widths, k)?;
        let mut tensors = Vec::new();
        for t in j.get("tensors")?.as_arr()? {
            let shape = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            let numel = t.get("numel")?.as_usize()?;
            if shape.iter().product::<usize>() != numel {
                bail!("tensor {}: shape/numel mismatch", t.get("name")?.as_str()?);
            }
            tensors.push(TensorMeta {
                name: t.get("name")?.as_str()?.to_string(),
                shape,
                numel,
                offset: t.get("offset")?.as_usize()?,
                min: t.get("min")?.as_f64()? as f32,
                max: t.get("max")?.as_f64()? as f32,
            });
        }
        if tensors.is_empty() {
            bail!("manifest has no tensors");
        }
        // offsets must be contiguous
        let mut off = 0;
        for t in &tensors {
            if t.offset != off {
                bail!("tensor {} offset {} != expected {off}", t.name, t.offset);
            }
            off += t.numel;
        }
        Ok(Self {
            model: j.get("model")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            k,
            schedule,
            tensors,
        })
    }
}

/// One fragment's frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragmentHeader {
    pub stage: u8,
    pub tensor: u16,
    pub len: u32,
    pub crc32: u32,
}

impl FragmentHeader {
    pub fn encode(&self) -> [u8; FRAG_HEADER_LEN] {
        let mut out = [0u8; FRAG_HEADER_LEN];
        out[0] = self.stage;
        out[1] = 0; // pad
        out[2..4].copy_from_slice(&self.tensor.to_le_bytes());
        out[4..8].copy_from_slice(&self.len.to_le_bytes());
        out[8..12].copy_from_slice(&self.crc32.to_le_bytes());
        out
    }

    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() < FRAG_HEADER_LEN {
            bail!("fragment header truncated");
        }
        Ok(Self {
            stage: b[0],
            tensor: u16::from_le_bytes([b[2], b[3]]),
            len: u32::from_le_bytes([b[4], b[5], b[6], b[7]]),
            crc32: u32::from_le_bytes([b[8], b[9], b[10], b[11]]),
        })
    }
}

/// Helper: build a manifest from raw weights + a schedule (encoder side).
pub fn manifest_from_weights(
    model: &str,
    task: &str,
    tensors: &[(String, Vec<usize>)],
    flat: &[f32],
    schedule: Schedule,
) -> Result<PnetManifest> {
    let mut metas = Vec::new();
    let mut off = 0;
    for (name, shape) in tensors {
        let numel: usize = shape.iter().product();
        if off + numel > flat.len() {
            bail!("weights too short for tensor {name}");
        }
        let qp = QuantParams::from_data(&flat[off..off + numel], K);
        metas.push(TensorMeta {
            name: name.clone(),
            shape: shape.clone(),
            numel,
            offset: off,
            min: qp.min,
            max: qp.max,
        });
        off += numel;
    }
    if off != flat.len() {
        bail!("weights length {} != manifest total {off}", flat.len());
    }
    Ok(PnetManifest {
        model: model.to_string(),
        task: task.to_string(),
        k: K,
        schedule,
        tensors: metas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> PnetManifest {
        manifest_from_weights(
            "m",
            "classify",
            &[
                ("a.w".to_string(), vec![4, 8]),
                ("a.b".to_string(), vec![8]),
            ],
            &(0..40).map(|i| i as f32 * 0.1).collect::<Vec<_>>(),
            Schedule::paper_default(),
        )
        .unwrap()
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = sample_manifest();
        let j = m.to_json();
        let m2 = PnetManifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn fragment_header_roundtrip() {
        let h = FragmentHeader {
            stage: 3,
            tensor: 517,
            len: 123_456,
            crc32: 0xDEADBEEF,
        };
        assert_eq!(FragmentHeader::decode(&h.encode()).unwrap(), h);
    }

    #[test]
    fn payload_accounting() {
        let m = sample_manifest();
        assert_eq!(m.param_count(), 40);
        // 16 bits over 40 elements = 80 bytes total payload
        assert_eq!(m.payload_bytes(), 80);
        let per_stage: usize = (0..8).map(|s| m.stage_payload_bytes(s)).sum();
        assert_eq!(per_stage, m.payload_bytes());
    }

    #[test]
    fn bad_manifests_rejected() {
        let m = sample_manifest();
        let mut j = m.to_json().to_string();
        j = j.replace("\"numel\":32", "\"numel\":31");
        assert!(PnetManifest::from_json(&Json::parse(&j).unwrap()).is_err());
    }

    #[test]
    fn weights_length_mismatch_rejected() {
        let r = manifest_from_weights(
            "m",
            "classify",
            &[("a".to_string(), vec![10])],
            &[0.0; 9],
            Schedule::paper_default(),
        );
        assert!(r.is_err());
    }
}
