//! `.pnet` header and manifest structures.

#![forbid(unsafe_code)]

use std::ops::Range;

use anyhow::{bail, Result};

use crate::quant::{QuantParams, Schedule, K};
use crate::util::json::{self, Json};

pub const MAGIC: &[u8; 4] = b"PNET";
pub const VERSION: u16 = 1;
/// stage u8 + pad u8 + tensor u16 + len u32 + crc u32 = 12 bytes
pub const FRAG_HEADER_LEN: usize = 12;

/// Per-tensor metadata carried in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub offset: usize,
    pub min: f32,
    pub max: f32,
}

impl TensorMeta {
    pub fn quant_params(&self, k: u32) -> QuantParams {
        QuantParams {
            min: self.min,
            max: self.max,
            k,
        }
    }
}

/// The `.pnet` manifest: everything a client needs to reconstruct.
#[derive(Debug, Clone, PartialEq)]
pub struct PnetManifest {
    pub model: String,
    pub task: String,
    pub k: u32,
    pub schedule: Schedule,
    pub tensors: Vec<TensorMeta>,
    /// Layer-granular ordering annotation (`LayerMajor`): tensors per
    /// layer, in tensor order. `Some(counts)` marks the ragged layer
    /// boundaries inside each stage — tensors are already laid out layer
    /// by layer, so the fragment wire order is unchanged and the body
    /// stays byte-identical to an unannotated (v1 stage-major) container;
    /// only the manifest JSON in the preamble grows by this key. Clients
    /// use it to emit `LayerReady` events and to begin executing layer 0
    /// while later layers are still in flight. `None` = v1 stage-major.
    pub layers: Option<Vec<usize>>,
}

impl PnetManifest {
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel).sum()
    }

    /// Total payload bytes (all fragments, without framing).
    pub fn payload_bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| self.schedule.total_bytes(t.numel))
            .sum()
    }

    /// Payload bytes of one stage across all tensors.
    pub fn stage_payload_bytes(&self, stage: usize) -> usize {
        self.tensors
            .iter()
            .map(|t| self.schedule.plane_bytes(stage, t.numel))
            .sum()
    }

    /// Wire bytes including framing and manifest.
    pub fn wire_bytes(&self) -> usize {
        let frames = self.schedule.stages() * self.tensors.len() * FRAG_HEADER_LEN;
        8 + 4 + self.to_json().to_string().len() + frames + self.payload_bytes()
    }

    /// Byte-range index of the container this manifest describes.
    pub fn stage_index(&self) -> StageIndex {
        StageIndex::from_manifest(self)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", json::s(&self.model)),
            ("task", json::s(&self.task)),
            ("k", json::num(self.k as f64)),
            (
                "schedule",
                json::arr(
                    self.schedule
                        .widths()
                        .iter()
                        .map(|&w| json::num(w as f64))
                        .collect(),
                ),
            ),
            (
                "tensors",
                json::arr(
                    self.tensors
                        .iter()
                        .map(|t| {
                            json::obj(vec![
                                ("name", json::s(&t.name)),
                                (
                                    "shape",
                                    json::arr(
                                        t.shape.iter().map(|&d| json::num(d as f64)).collect(),
                                    ),
                                ),
                                ("numel", json::num(t.numel as f64)),
                                ("offset", json::num(t.offset as f64)),
                                ("min", json::num(t.min as f64)),
                                ("max", json::num(t.max as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(layers) = &self.layers {
            pairs.push((
                "layers",
                json::arr(layers.iter().map(|&n| json::num(n as f64)).collect()),
            ));
        }
        json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let k = j.get("k")?.as_i64()? as u32;
        if k == 0 || k > 32 {
            bail!("invalid k={k}");
        }
        let widths = j
            .get("schedule")?
            .as_arr()?
            .iter()
            .map(|w| Ok(w.as_i64()? as u32))
            .collect::<Result<Vec<_>>>()?;
        let schedule = Schedule::new(widths, k)?;
        let mut tensors = Vec::new();
        for t in j.get("tensors")?.as_arr()? {
            let shape = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            let numel = t.get("numel")?.as_usize()?;
            if shape.iter().product::<usize>() != numel {
                bail!("tensor {}: shape/numel mismatch", t.get("name")?.as_str()?);
            }
            tensors.push(TensorMeta {
                name: t.get("name")?.as_str()?.to_string(),
                shape,
                numel,
                offset: t.get("offset")?.as_usize()?,
                min: t.get("min")?.as_f64()? as f32,
                max: t.get("max")?.as_f64()? as f32,
            });
        }
        if tensors.is_empty() {
            bail!("manifest has no tensors");
        }
        // offsets must be contiguous
        let mut off = 0;
        for t in &tensors {
            if t.offset != off {
                bail!("tensor {} offset {} != expected {off}", t.name, t.offset);
            }
            off += t.numel;
        }
        let layers = match j.opt("layers") {
            None => None,
            Some(l) => {
                let counts = l
                    .as_arr()?
                    .iter()
                    .map(|c| c.as_usize())
                    .collect::<Result<Vec<_>>>()?;
                if counts.iter().any(|&c| c == 0) {
                    bail!("layer annotation contains an empty layer");
                }
                if counts.iter().sum::<usize>() != tensors.len() {
                    bail!(
                        "layer annotation covers {} tensors, manifest has {}",
                        counts.iter().sum::<usize>(),
                        tensors.len()
                    );
                }
                Some(counts)
            }
        };
        Ok(Self {
            model: j.get("model")?.as_str()?.to_string(),
            task: j.get("task")?.as_str()?.to_string(),
            k,
            schedule,
            tensors,
            layers,
        })
    }

    /// Annotate this manifest with inferred layer groups
    /// ([`infer_layer_groups`]), switching it to `LayerMajor` ordering.
    pub fn with_inferred_layers(mut self) -> Self {
        let shapes: Vec<&[usize]> = self.tensors.iter().map(|t| t.shape.as_slice()).collect();
        self.layers = Some(infer_layer_groups(&shapes));
        self
    }
}

/// Group a tensor sequence into model layers by shape rank: a tensor of
/// rank ≥ 2 (dense / conv kernel) starts a new layer, and rank-≤1
/// tensors (biases) join the layer in progress. This matches how the
/// reference runtime derives its layer graph (`runtime::reference::plan`:
/// kernel + optional bias per layer), so the groups line up one-to-one
/// with executable layers for plannable models.
///
/// Returns tensors-per-layer counts (the `layers` manifest field).
pub fn infer_layer_groups(shapes: &[&[usize]]) -> Vec<usize> {
    let mut counts: Vec<usize> = Vec::new();
    for shape in shapes {
        if shape.len() >= 2 || counts.is_empty() {
            counts.push(1);
        } else {
            *counts.last_mut().expect("non-empty") += 1;
        }
    }
    counts
}

/// Derived byte-range index of a stage-major `.pnet` container: where the
/// preamble ends and where every (stage, tensor) frame lives.
///
/// The index is fully determined by the manifest — the JSON serialization
/// is deterministic and the frame layout is fixed — so it costs no wire
/// bytes: the server computes it once per encoding to answer stage-range
/// requests with borrowed slices, and a client can compute it from the
/// manifest to know exactly which byte every stage starts at.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageIndex {
    preamble_len: usize,
    /// absolute start of each stage's first frame; one extra final entry
    /// equals the container's total length
    stage_starts: Vec<usize>,
    /// `frame_starts[stage][tensor]`: absolute start of the frame header
    frame_starts: Vec<Vec<usize>>,
    /// `payload_lens[stage][tensor]`: packed plane bytes of that fragment
    payload_lens: Vec<Vec<usize>>,
    /// `LayerMajor` ragged boundaries: tensor index where each layer
    /// starts, plus one final entry = tensor count. Empty when the
    /// manifest carries no layer annotation (v1 stage-major).
    layer_bounds: Vec<usize>,
}

impl StageIndex {
    /// Compute the index for a container encoded from `manifest`.
    pub fn from_manifest(manifest: &PnetManifest) -> Self {
        let preamble_len = 12 + manifest.to_json().to_string().len();
        let stages = manifest.schedule.stages();
        let mut stage_starts = Vec::with_capacity(stages + 1);
        let mut frame_starts = Vec::with_capacity(stages);
        let mut payload_lens = Vec::with_capacity(stages);
        let mut off = preamble_len;
        for s in 0..stages {
            stage_starts.push(off);
            let mut fs = Vec::with_capacity(manifest.tensors.len());
            let mut pl = Vec::with_capacity(manifest.tensors.len());
            for t in &manifest.tensors {
                fs.push(off);
                let plen = manifest.schedule.plane_bytes(s, t.numel);
                pl.push(plen);
                off += FRAG_HEADER_LEN + plen;
            }
            frame_starts.push(fs);
            payload_lens.push(pl);
        }
        stage_starts.push(off);
        let layer_bounds = match &manifest.layers {
            None => Vec::new(),
            Some(counts) => {
                let mut bounds = Vec::with_capacity(counts.len() + 1);
                let mut at = 0;
                bounds.push(0);
                for &c in counts {
                    at += c;
                    bounds.push(at);
                }
                debug_assert_eq!(at, manifest.tensors.len());
                bounds
            }
        };
        Self {
            preamble_len,
            stage_starts,
            frame_starts,
            payload_lens,
            layer_bounds,
        }
    }

    pub fn stages(&self) -> usize {
        self.frame_starts.len()
    }

    pub fn tensors(&self) -> usize {
        self.frame_starts.first().map_or(0, |fs| fs.len())
    }

    /// Bytes of the preamble (magic + version + flags + manifest).
    pub fn preamble_len(&self) -> usize {
        self.preamble_len
    }

    /// Total container length in bytes.
    pub fn total_len(&self) -> usize {
        *self.stage_starts.last().expect("stage_starts never empty")
    }

    /// One frame (header + payload) of a (stage, tensor) fragment.
    pub fn frame_range(&self, stage: usize, tensor: usize) -> Range<usize> {
        let start = self.frame_starts[stage][tensor];
        start..start + FRAG_HEADER_LEN + self.payload_lens[stage][tensor]
    }

    /// Payload bytes (without the frame header) of a (stage, tensor) fragment.
    pub fn payload_range(&self, stage: usize, tensor: usize) -> Range<usize> {
        let r = self.frame_range(stage, tensor);
        r.start + FRAG_HEADER_LEN..r.end
    }

    /// Frames of stages `[a, b)` — contiguous because the container is
    /// stage-major.
    pub fn stage_span(&self, a: usize, b: usize) -> Result<Range<usize>> {
        if a >= b || b > self.stages() {
            bail!(
                "invalid stage range [{a}, {b}) for {}-stage container",
                self.stages()
            );
        }
        Ok(self.stage_starts[a]..self.stage_starts[b])
    }

    /// Number of annotated layers; 0 for an unannotated (v1) container.
    pub fn layers(&self) -> usize {
        self.layer_bounds.len().saturating_sub(1)
    }

    /// Tensor indices belonging to `layer` (layers are contiguous tensor
    /// runs, so this is a range).
    pub fn layer_tensor_range(&self, layer: usize) -> Result<Range<usize>> {
        if layer + 1 >= self.layer_bounds.len() {
            bail!("layer {layer} out of range for {}-layer index", self.layers());
        }
        Ok(self.layer_bounds[layer]..self.layer_bounds[layer + 1])
    }

    /// Byte run of one layer's frames within one stage. Contiguous
    /// because layers are contiguous tensor runs and frames within a
    /// stage follow tensor order — this is the slice whose arrival makes
    /// `(layer, stage)` executable, and the unit the streaming executor
    /// blocks on.
    pub fn layer_span(&self, stage: usize, layer: usize) -> Result<Range<usize>> {
        if stage >= self.stages() {
            bail!("stage {stage} out of range");
        }
        let tensors = self.layer_tensor_range(layer)?;
        let start = self.frame_starts[stage][tensors.start];
        let end = self.frame_range(stage, tensors.end - 1).end;
        Ok(start..end)
    }

    /// Response body for a stage-range request: preamble + frames when the
    /// range starts at stage 0 (fresh fetch needs the manifest), frames
    /// only otherwise (a resuming client already holds the manifest).
    pub fn body_range(&self, stages: Option<(u32, u32)>) -> Result<Range<usize>> {
        match stages {
            None => Ok(0..self.total_len()),
            Some((a, b)) => {
                let span = self.stage_span(a as usize, b as usize)?;
                Ok(if a == 0 { 0..span.end } else { span })
            }
        }
    }
}

/// One fragment's frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragmentHeader {
    pub stage: u8,
    pub tensor: u16,
    pub len: u32,
    pub crc32: u32,
}

impl FragmentHeader {
    pub fn encode(&self) -> [u8; FRAG_HEADER_LEN] {
        let mut out = [0u8; FRAG_HEADER_LEN];
        out[0] = self.stage;
        out[1] = 0; // pad
        out[2..4].copy_from_slice(&self.tensor.to_le_bytes());
        out[4..8].copy_from_slice(&self.len.to_le_bytes());
        out[8..12].copy_from_slice(&self.crc32.to_le_bytes());
        out
    }

    pub fn decode(b: &[u8]) -> Result<Self> {
        if b.len() < FRAG_HEADER_LEN {
            bail!("fragment header truncated");
        }
        Ok(Self {
            stage: b[0],
            tensor: u16::from_le_bytes([b[2], b[3]]),
            len: u32::from_le_bytes([b[4], b[5], b[6], b[7]]),
            crc32: u32::from_le_bytes([b[8], b[9], b[10], b[11]]),
        })
    }
}

/// Helper: build a manifest from raw weights + a schedule (encoder side).
pub fn manifest_from_weights(
    model: &str,
    task: &str,
    tensors: &[(String, Vec<usize>)],
    flat: &[f32],
    schedule: Schedule,
) -> Result<PnetManifest> {
    let mut metas = Vec::new();
    let mut off = 0;
    for (name, shape) in tensors {
        let numel: usize = shape.iter().product();
        if off + numel > flat.len() {
            bail!("weights too short for tensor {name}");
        }
        let qp = QuantParams::from_data(&flat[off..off + numel], K);
        metas.push(TensorMeta {
            name: name.clone(),
            shape: shape.clone(),
            numel,
            offset: off,
            min: qp.min,
            max: qp.max,
        });
        off += numel;
    }
    if off != flat.len() {
        bail!("weights length {} != manifest total {off}", flat.len());
    }
    Ok(PnetManifest {
        model: model.to_string(),
        task: task.to_string(),
        k: K,
        schedule,
        tensors: metas,
        layers: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> PnetManifest {
        manifest_from_weights(
            "m",
            "classify",
            &[
                ("a.w".to_string(), vec![4, 8]),
                ("a.b".to_string(), vec![8]),
            ],
            &(0..40).map(|i| i as f32 * 0.1).collect::<Vec<_>>(),
            Schedule::paper_default(),
        )
        .unwrap()
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = sample_manifest();
        let j = m.to_json();
        let m2 = PnetManifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn fragment_header_roundtrip() {
        let h = FragmentHeader {
            stage: 3,
            tensor: 517,
            len: 123_456,
            crc32: 0xDEADBEEF,
        };
        assert_eq!(FragmentHeader::decode(&h.encode()).unwrap(), h);
    }

    #[test]
    fn payload_accounting() {
        let m = sample_manifest();
        assert_eq!(m.param_count(), 40);
        // 16 bits over 40 elements = 80 bytes total payload
        assert_eq!(m.payload_bytes(), 80);
        let per_stage: usize = (0..8).map(|s| m.stage_payload_bytes(s)).sum();
        assert_eq!(per_stage, m.payload_bytes());
    }

    #[test]
    fn stage_index_accounting() {
        let m = sample_manifest();
        let idx = m.stage_index();
        assert_eq!(idx.stages(), 8);
        assert_eq!(idx.tensors(), 2);
        assert_eq!(idx.total_len(), m.wire_bytes());
        assert_eq!(idx.preamble_len(), 12 + m.to_json().to_string().len());
        // frames tile the body contiguously, stage-major
        let mut off = idx.preamble_len();
        for s in 0..idx.stages() {
            assert_eq!(idx.stage_span(s, s + 1).unwrap().start, off);
            for t in 0..idx.tensors() {
                let fr = idx.frame_range(s, t);
                assert_eq!(fr.start, off);
                let pr = idx.payload_range(s, t);
                assert_eq!(pr.start, fr.start + FRAG_HEADER_LEN);
                assert_eq!(pr.end, fr.end);
                assert_eq!(pr.len(), m.schedule.plane_bytes(s, m.tensors[t].numel));
                off = fr.end;
            }
            assert_eq!(idx.stage_span(s, s + 1).unwrap().end, off);
        }
        assert_eq!(off, idx.total_len());
        // spans concatenate
        let whole = idx.stage_span(0, 8).unwrap();
        assert_eq!(whole.end, idx.total_len());
        assert!(idx.stage_span(3, 3).is_err());
        assert!(idx.stage_span(0, 9).is_err());
    }

    #[test]
    fn body_range_semantics() {
        let m = sample_manifest();
        let idx = m.stage_index();
        // full fetch = whole container
        assert_eq!(idx.body_range(None).unwrap(), 0..idx.total_len());
        // range from stage 0 includes the preamble
        let r0 = idx.body_range(Some((0, 2))).unwrap();
        assert_eq!(r0.start, 0);
        assert_eq!(r0.end, idx.stage_span(0, 2).unwrap().end);
        // later ranges are frames only
        let r1 = idx.body_range(Some((2, 5))).unwrap();
        assert_eq!(r1, idx.stage_span(2, 5).unwrap());
        assert!(idx.body_range(Some((5, 5))).is_err());
        assert!(idx.body_range(Some((0, 99))).is_err());
    }

    #[test]
    fn layer_groups_inferred_by_rank() {
        // kernel starts a layer, bias joins it; a leading bias still
        // forms a group of its own
        assert_eq!(
            infer_layer_groups(&[&[3, 3, 2, 8][..], &[8], &[128, 10], &[10]]),
            vec![2, 2]
        );
        assert_eq!(infer_layer_groups(&[&[16, 12][..], &[12, 10]]), vec![1, 1]);
        assert_eq!(infer_layer_groups(&[&[8][..], &[8, 4]]), vec![1, 1]);
        assert_eq!(infer_layer_groups(&[]), Vec::<usize>::new());
    }

    #[test]
    fn layer_annotation_roundtrips_and_validates() {
        let m = sample_manifest().with_inferred_layers();
        assert_eq!(m.layers, Some(vec![2])); // a.w [4,8] + a.b [8]
        let j = m.to_json();
        let m2 = PnetManifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m, m2);
        // annotation must tile the tensor list exactly
        let bad = j.to_string().replace("\"layers\":[2]", "\"layers\":[1]");
        assert!(PnetManifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        let empty = j.to_string().replace("\"layers\":[2]", "\"layers\":[0,2]");
        assert!(PnetManifest::from_json(&Json::parse(&empty).unwrap()).is_err());
    }

    #[test]
    fn layer_annotation_changes_only_the_preamble() {
        let plain = sample_manifest();
        let annotated = plain.clone().with_inferred_layers();
        // identical fragment geometry: same payloads, same frame layout
        assert_eq!(plain.payload_bytes(), annotated.payload_bytes());
        let ip = plain.stage_index();
        let ia = annotated.stage_index();
        let delta = ia.preamble_len() - ip.preamble_len();
        assert!(delta > 0, "layers key must serialize");
        assert_eq!(ia.total_len() - ip.total_len(), delta);
        for s in 0..ip.stages() {
            for t in 0..ip.tensors() {
                let fp = ip.frame_range(s, t);
                let fa = ia.frame_range(s, t);
                assert_eq!(fa.start - fp.start, delta);
                assert_eq!(fa.len(), fp.len());
            }
        }
    }

    #[test]
    fn layer_spans_tile_each_stage() {
        let m = manifest_from_weights(
            "lm",
            "classify",
            &[
                ("c1.w".to_string(), vec![3, 3, 1, 4]),
                ("c1.b".to_string(), vec![4]),
                ("h.w".to_string(), vec![16, 5]),
                ("h.b".to_string(), vec![5]),
            ],
            &(0..(36 + 4 + 80 + 5)).map(|i| i as f32 * 0.01).collect::<Vec<_>>(),
            Schedule::paper_default(),
        )
        .unwrap()
        .with_inferred_layers();
        let idx = m.stage_index();
        assert_eq!(idx.layers(), 2);
        assert_eq!(idx.layer_tensor_range(0).unwrap(), 0..2);
        assert_eq!(idx.layer_tensor_range(1).unwrap(), 2..4);
        for s in 0..idx.stages() {
            let span = idx.stage_span(s, s + 1).unwrap();
            let l0 = idx.layer_span(s, 0).unwrap();
            let l1 = idx.layer_span(s, 1).unwrap();
            assert_eq!(l0.start, span.start);
            assert_eq!(l0.end, l1.start, "layer spans tile stage {s}");
            assert_eq!(l1.end, span.end);
        }
        assert!(idx.layer_span(0, 2).is_err());
        assert!(idx.layer_span(99, 0).is_err());
        // unannotated index exposes no layers
        let plain = sample_manifest().stage_index();
        assert_eq!(plain.layers(), 0);
        assert!(plain.layer_span(0, 0).is_err());
    }

    #[test]
    fn bad_manifests_rejected() {
        let m = sample_manifest();
        let mut j = m.to_json().to_string();
        j = j.replace("\"numel\":32", "\"numel\":31");
        assert!(PnetManifest::from_json(&Json::parse(&j).unwrap()).is_err());
    }

    #[test]
    fn weights_length_mismatch_rejected() {
        let r = manifest_from_weights(
            "m",
            "classify",
            &[("a".to_string(), vec![10])],
            &[0.0; 9],
            Schedule::paper_default(),
        );
        assert!(r.is_err());
    }
}
