//! `.pnet` encoder: float weights → quantize → bit-divide → framed bytes.

#![forbid(unsafe_code)]

use std::io::Write;

use anyhow::{bail, Result};

use super::header::{FragmentHeader, PnetManifest, StageIndex, MAGIC, VERSION};
use crate::quant::{bitplane, quantize};

/// Progressive model encoder.
///
/// Owns the manifest and quantized codes; can emit the full container to
/// any `Write`, or hand out individual fragments for streaming.
pub struct PnetWriter {
    manifest: PnetManifest,
    /// per-tensor packed planes, `planes[tensor][stage]`
    planes: Vec<Vec<Vec<u8>>>,
}

impl PnetWriter {
    /// Quantize + bit-divide `flat` according to `manifest`.
    pub fn encode(manifest: PnetManifest, flat: &[f32]) -> Result<Self> {
        if flat.len() != manifest.param_count() {
            bail!(
                "weights have {} params, manifest expects {}",
                flat.len(),
                manifest.param_count()
            );
        }
        let mut planes = Vec::with_capacity(manifest.tensors.len());
        for t in &manifest.tensors {
            let seg = &flat[t.offset..t.offset + t.numel];
            let q = quantize::quantize(seg, &t.quant_params(manifest.k));
            planes.push(bitplane::encode_planes(&q, &manifest.schedule));
        }
        Ok(Self { manifest, planes })
    }

    pub fn manifest(&self) -> &PnetManifest {
        &self.manifest
    }

    /// Byte-range index of the container `to_bytes`/`write_to` emit.
    pub fn stage_index(&self) -> StageIndex {
        self.manifest.stage_index()
    }

    /// A single fragment's packed payload.
    pub fn fragment(&self, stage: usize, tensor: usize) -> &[u8] {
        &self.planes[tensor][stage]
    }

    /// Frame one fragment (header + payload).
    pub fn framed_fragment(&self, stage: usize, tensor: usize) -> Vec<u8> {
        let payload = self.fragment(stage, tensor);
        let header = FragmentHeader {
            stage: stage as u8,
            tensor: tensor as u16,
            len: payload.len() as u32,
            crc32: crate::util::crc32::hash(payload),
        };
        let mut out = Vec::with_capacity(payload.len() + 12);
        out.extend_from_slice(&header.encode());
        out.extend_from_slice(payload);
        out
    }

    /// Container preamble: magic, version, manifest.
    pub fn preamble(&self) -> Vec<u8> {
        let manifest_json = self.manifest.to_json().to_string();
        let mut out = Vec::with_capacity(12 + manifest_json.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&(manifest_json.len() as u32).to_le_bytes());
        out.extend_from_slice(manifest_json.as_bytes());
        out
    }

    /// Write the complete container, stage-major.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<u64> {
        let mut written = 0u64;
        let pre = self.preamble();
        w.write_all(&pre)?;
        written += pre.len() as u64;
        for stage in 0..self.manifest.schedule.stages() {
            for tensor in 0..self.manifest.tensors.len() {
                let frame = self.framed_fragment(stage, tensor);
                w.write_all(&frame)?;
                written += frame.len() as u64;
            }
        }
        Ok(written)
    }

    /// Serialize to a byte vector.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.write_to(&mut out).expect("vec write");
        out
    }

    /// Write to a file.
    pub fn write_file(&self, path: &std::path::Path) -> Result<u64> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let n = self.write_to(&mut f)?;
        f.flush()?;
        Ok(n)
    }

    /// Bytes that arrive before the first full stage is available
    /// (preamble + stage 0 frames).
    ///
    /// Derived from the [`StageIndex`] rather than re-summed from the
    /// schedule, so it tracks the active ordering mode's framing: a
    /// `LayerMajor` (layer-annotated) manifest serializes a longer
    /// preamble, which the old hand-summed formula silently ignored.
    pub fn first_stage_wire_bytes(&self) -> usize {
        self.stage_index()
            .body_range(Some((0, 1)))
            .expect("stage 0 always exists")
            .end
    }

    /// Bytes that arrive before layer 0 first becomes executable
    /// (preamble + layer 0's stage-0 frames). This is the transfer the
    /// streaming executor's time-to-first-inference is bounded by.
    /// Errors unless the manifest carries a layer annotation.
    pub fn first_layer_wire_bytes(&self) -> Result<usize> {
        Ok(self.stage_index().layer_span(0, 0)?.end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::header::manifest_from_weights;
    use crate::quant::Schedule;
    use crate::util::rng::Rng;

    pub(crate) fn sample(seed: u64) -> (PnetManifest, Vec<f32>) {
        let mut r = Rng::new(seed);
        let flat: Vec<f32> = (0..1000).map(|_| r.normal() as f32).collect();
        let manifest = manifest_from_weights(
            "toy",
            "classify",
            &[
                ("w1".to_string(), vec![30, 20]),
                ("b1".to_string(), vec![20]),
                ("w2".to_string(), vec![20, 19]),
            ],
            &flat,
            Schedule::paper_default(),
        )
        .unwrap();
        (manifest, flat)
    }

    #[test]
    fn encode_and_fragment_sizes() {
        let (m, flat) = sample(1);
        let w = PnetWriter::encode(m.clone(), &flat).unwrap();
        for s in 0..m.schedule.stages() {
            for t in 0..m.tensors.len() {
                assert_eq!(
                    w.fragment(s, t).len(),
                    m.schedule.plane_bytes(s, m.tensors[t].numel)
                );
            }
        }
        let bytes = w.to_bytes();
        assert_eq!(bytes.len(), m.wire_bytes());
        assert_eq!(&bytes[..4], MAGIC);
    }

    #[test]
    fn stage_index_matches_emitted_bytes() {
        let (m, flat) = sample(4);
        let w = PnetWriter::encode(m.clone(), &flat).unwrap();
        let bytes = w.to_bytes();
        let idx = w.stage_index();
        assert_eq!(idx.total_len(), bytes.len());
        assert_eq!(&bytes[..idx.preamble_len()], &w.preamble()[..]);
        for s in 0..m.schedule.stages() {
            for t in 0..m.tensors.len() {
                assert_eq!(
                    &bytes[idx.frame_range(s, t)],
                    &w.framed_fragment(s, t)[..],
                    "frame ({s}, {t})"
                );
                assert_eq!(&bytes[idx.payload_range(s, t)], w.fragment(s, t));
            }
        }
        // stage spans concatenate back to the full body
        let mut rejoined = bytes[..idx.preamble_len()].to_vec();
        for s in 0..m.schedule.stages() {
            rejoined.extend_from_slice(&bytes[idx.stage_span(s, s + 1).unwrap()]);
        }
        assert_eq!(rejoined, bytes);
    }

    #[test]
    fn first_stage_wire_bytes_tracks_the_ordering_mode() {
        // Regression: the old formula hand-summed preamble + stage-0
        // payload + tensor framing, which is only right for a bare
        // stage-major manifest — a layer annotation lengthens the
        // preamble and the count must follow.
        let (m, flat) = sample(7);
        let plain = PnetWriter::encode(m.clone(), &flat).unwrap();
        let annotated = PnetWriter::encode(m.clone().with_inferred_layers(), &flat).unwrap();
        let hand_summed = |w: &PnetWriter| {
            w.preamble().len()
                + m.stage_payload_bytes(0)
                + m.tensors.len() * crate::format::header::FRAG_HEADER_LEN
        };
        // both modes: the reported count is exactly where stage 0 ends
        // in the emitted bytes
        for w in [&plain, &annotated] {
            assert_eq!(w.first_stage_wire_bytes(), hand_summed(w));
            assert_eq!(
                w.first_stage_wire_bytes(),
                w.stage_index().stage_span(0, 1).unwrap().end
            );
        }
        // the two modes differ by exactly the manifest growth
        let delta = annotated.preamble().len() - plain.preamble().len();
        assert!(delta > 0);
        assert_eq!(
            annotated.first_stage_wire_bytes() - plain.first_stage_wire_bytes(),
            delta
        );
        // layer accounting: first layer needs strictly fewer bytes than
        // the full first stage, and only exists under LayerMajor
        let first_layer = annotated.first_layer_wire_bytes().unwrap();
        assert!(first_layer > annotated.preamble().len());
        assert!(first_layer < annotated.first_stage_wire_bytes());
        assert!(plain.first_layer_wire_bytes().is_err());
    }

    #[test]
    fn layer_annotated_body_is_byte_identical() {
        // LayerMajor reorders nothing on the wire: tensors already sit
        // in layer order, so only the preamble (manifest JSON) differs.
        let (m, flat) = sample(8);
        let plain = PnetWriter::encode(m.clone(), &flat).unwrap();
        let annotated = PnetWriter::encode(m.with_inferred_layers(), &flat).unwrap();
        let pb = plain.to_bytes();
        let ab = annotated.to_bytes();
        assert_eq!(
            &pb[plain.stage_index().preamble_len()..],
            &ab[annotated.stage_index().preamble_len()..],
        );
    }

    #[test]
    fn wrong_weight_count_rejected() {
        let (m, flat) = sample(2);
        assert!(PnetWriter::encode(m, &flat[..999]).is_err());
    }

    #[test]
    fn size_overhead_is_small() {
        // Wire size ≈ payload size: framing+manifest < 6% for this tiny
        // model, <0.1% for real models.
        let (m, flat) = sample(3);
        let w = PnetWriter::encode(m.clone(), &flat).unwrap();
        let payload = m.payload_bytes();
        let wire = w.to_bytes().len();
        assert!(wire - payload < 1200, "overhead {}", wire - payload);
    }
}
