//! Deterministic schedule-exploring model checker (loom/CHESS-style).
//!
//! A *model run* executes a closure ("the body") on real OS threads that
//! are serialized by a baton: exactly one model thread runs at a time,
//! and before every visible operation (lock, unlock, condvar wait/notify,
//! atomic access, spawn, join, sleep) the thread hands control to the
//! scheduler, which decides who runs next. Because every context switch
//! is an explicit recorded *choice*, a whole interleaving is just a
//! sequence of small integers — which makes schedules enumerable
//! (bounded-exhaustive DFS), samplable (seeded random), and exactly
//! replayable (feed the recorded choices back in).
//!
//! The instrumentation hooks live in [`crate::analysis::shim`] and are
//! swapped in for `std::sync` by the [`crate::util::sync`] facade under
//! `--cfg prognet_check`; outside a model run (and in normal builds) the
//! shims defer to plain std, so the same test binary can mix model tests
//! with ordinary ones.
//!
//! Design points, and the deliberate limits of the model:
//!
//! - **Preemption bounding** (CHESS): schedules with more than
//!   [`Config::max_preemptions`] involuntary switches are pruned. Most
//!   concurrency bugs need very few preemptions; the default bound of 2
//!   keeps exhaustive search tractable.
//! - **Sequential consistency**: atomics are modeled as `SeqCst`
//!   regardless of the ordering the code requests. Weak-memory bugs are
//!   out of scope here and left to the TSan/Miri CI jobs; what this
//!   checker finds is interleaving bugs (lost updates, torn protocols,
//!   lost wakeups, deadlocks).
//! - **Virtual time**: `sleep` and condvar timeouts park the thread
//!   under a logical clock that only advances when no thread is
//!   runnable, so timeout paths explore in microseconds of real time.
//!   The clock is lazy — runnable threads may run past a sleeper's
//!   deadline before time jumps.
//! - **Deadlock and livelock detection**: no runnable thread and no
//!   pending deadline is reported as a deadlock with per-thread wait
//!   states; runs exceeding [`Config::max_steps`] scheduling points are
//!   reported as livelocks. Spin loops (rather than condvars) inside a
//!   model will trip the step budget by design.
//! - **No spurious wakeups**: condvar waiters wake only by notify or
//!   timeout. Code relying on spurious wakeups for progress would pass
//!   here and fail in production — the lint pass's job, not this one.
//!
//! See `rust/docs/ANALYSIS.md` for a worked example of writing a
//! schedule test and reproducing a failure from its printed trace.

#![forbid(unsafe_code)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Once};
use std::time::{Duration, Instant};

/// Panic payload used to unwind model threads once a run is being torn
/// down (failure found, or schedule abandoned). Never reported as a
/// failure itself.
const ABORT_SENTINEL: &str = "__prognet_sched_abort__";

/// Process-wide resource id counter. Ids only need to be unique, not
/// dense — traces normalize them to first-seen order when rendering.
static NEXT_RESOURCE: AtomicUsize = AtomicUsize::new(1 << 20);

/// A fresh id for a lock/condvar/cell the scheduler should track.
pub fn new_resource_id() -> usize {
    NEXT_RESOURCE.fetch_add(1, Ordering::SeqCst)
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<ModelState>, usize)>> = const { RefCell::new(None) };
}

/// The scheduler handle of the calling thread, when it is a model
/// thread. The shims use this to decide instrumented vs plain-std paths.
/// Public for the shim/facade layer only — not a stable API.
#[doc(hidden)]
pub fn current() -> Option<(Arc<ModelState>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Is the calling thread part of a model run?
pub fn in_model() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

// ---------------------------------------------------------------------------
// Public API surface: configuration, reports, module-level ops
// ---------------------------------------------------------------------------

/// Exploration strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Depth-first enumeration of all schedules within the preemption
    /// bound (deterministic; sets [`Report::exhausted`] when complete).
    Exhaustive,
    /// Independent runs driven by a splitmix64 PRNG; the per-run seed is
    /// recorded so any failure is replayable.
    Random,
}

/// Model-checking configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub strategy: Strategy,
    /// Maximum schedules to execute before giving up.
    pub max_iterations: usize,
    /// CHESS-style preemption bound (`None` = unbounded).
    pub max_preemptions: Option<usize>,
    /// Scheduling points allowed per run before declaring a livelock.
    pub max_steps: usize,
    /// Base seed for [`Strategy::Random`].
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            strategy: Strategy::Exhaustive,
            max_iterations: 2000,
            max_preemptions: Some(2),
            max_steps: 20_000,
            seed: 0x5DEE_CE66_D1CE_CAFE,
        }
    }
}

/// One recorded scheduling step (who did what to which resource).
/// Resource ids are arbitrary labels, stable within a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceStep {
    pub tid: usize,
    pub op: &'static str,
    pub res: usize,
}

/// A failing schedule: everything needed to reproduce and read it.
#[derive(Debug, Clone)]
pub struct Failure {
    /// Panic/assertion message, or the deadlock/livelock diagnosis.
    pub message: String,
    /// The choice sequence that produced the failure — feed to
    /// [`replay`] (or `PROGNET_SCHED_REPLAY` via [`check`]).
    pub schedule: Vec<u32>,
    /// The per-run PRNG seed, when the failing run came from
    /// [`Strategy::Random`].
    pub seed: Option<u64>,
    /// Full step trace of the failing run.
    pub trace: Vec<TraceStep>,
}

impl Failure {
    /// Human-readable report: message, replayable schedule, step trace.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "model check failed: {}", self.message);
        let sched: Vec<String> = self.schedule.iter().map(|c| c.to_string()).collect();
        let _ = writeln!(out, "schedule: [{}]", sched.join(","));
        if let Some(s) = self.seed {
            let _ = writeln!(out, "seed: {s:#018x}");
        }
        let _ = writeln!(
            out,
            "replay: sched::replay(&[{}], body) or PROGNET_SCHED_REPLAY={}",
            sched.join(","),
            sched.join(",")
        );
        let start = self.trace.len().saturating_sub(200);
        if start > 0 {
            let _ = writeln!(out, "trace: ({start} earlier steps elided)");
        } else {
            let _ = writeln!(out, "trace:");
        }
        let mut labels: HashMap<usize, usize> = HashMap::new();
        for (i, s) in self.trace.iter().enumerate() {
            let n = labels.len();
            let label = *labels.entry(s.res).or_insert(n);
            if i >= start {
                let _ = writeln!(out, "  #{i:04} t{} {:<16} r{label}", s.tid, s.op);
            }
        }
        out
    }
}

/// Result of an exploration.
#[derive(Debug, Clone)]
pub struct Report {
    /// Schedules executed.
    pub schedules: usize,
    /// True when exhaustive search covered the whole bounded space.
    pub exhausted: bool,
    /// First failing schedule found, if any (exploration stops there).
    pub failure: Option<Failure>,
    /// Choice sequence of every executed schedule, in order.
    pub schedules_taken: Vec<Vec<u32>>,
    /// Normalized trace digest of every executed schedule (two runs of
    /// the same program under the same choices digest identically).
    pub trace_digests: Vec<u64>,
}

/// Explore interleavings of `body` under `cfg`. The body runs many
/// times, once per schedule; it must set up its own state each run and
/// create its threads via [`spawn`].
pub fn explore<F>(cfg: Config, body: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    let body = Arc::new(body);
    let mut report = Report {
        schedules: 0,
        exhausted: false,
        failure: None,
        schedules_taken: Vec::new(),
        trace_digests: Vec::new(),
    };
    match cfg.strategy {
        Strategy::Exhaustive => {
            let mut prefix: Vec<u32> = Vec::new();
            while report.schedules < cfg.max_iterations {
                let out = run_once(&cfg, std::mem::take(&mut prefix), None, body.clone());
                record(&mut report, &out);
                if let Some(msg) = out.failure {
                    report.failure = Some(make_failure(msg, &out, None));
                    break;
                }
                // Backtrack: deepest choice with an unexplored sibling.
                let mut ch = out.choices;
                loop {
                    match ch.last_mut() {
                        None => {
                            report.exhausted = true;
                            break;
                        }
                        Some(last) if last.chosen + 1 < last.options => {
                            last.chosen += 1;
                            break;
                        }
                        Some(_) => {
                            ch.pop();
                        }
                    }
                }
                if report.exhausted {
                    break;
                }
                prefix = ch.iter().map(|c| c.chosen).collect();
            }
        }
        Strategy::Random => {
            for i in 0..cfg.max_iterations {
                let seed = mix_seed(cfg.seed, i as u64);
                let out = run_once(&cfg, Vec::new(), Some(seed), body.clone());
                record(&mut report, &out);
                if let Some(msg) = out.failure {
                    report.failure = Some(make_failure(msg, &out, Some(seed)));
                    break;
                }
            }
        }
    }
    report
}

/// Run exactly one schedule, following `schedule` while it lasts and
/// continuing deterministically (first option) past its end. Returns the
/// failure, if that schedule produces one.
pub fn replay<F>(schedule: &[u32], body: F) -> Option<Failure>
where
    F: Fn() + Send + Sync + 'static,
{
    let cfg = Config::default();
    let out = run_once(&cfg, schedule.to_vec(), None, Arc::new(body));
    let failure = out.failure.clone();
    failure.map(|msg| make_failure(msg, &out, None))
}

/// Run exactly one randomly-scheduled run pinned to `seed`.
pub fn replay_seed<F>(seed: u64, body: F) -> Option<Failure>
where
    F: Fn() + Send + Sync + 'static,
{
    let cfg = Config::default();
    let out = run_once(&cfg, Vec::new(), Some(seed), Arc::new(body));
    let failure = out.failure.clone();
    failure.map(|msg| make_failure(msg, &out, Some(seed)))
}

/// Explore with defaults and panic with a rendered trace on failure.
/// `PROGNET_SCHED_REPLAY="0,1,0,2"` switches to single-schedule replay.
pub fn check<F>(body: F)
where
    F: Fn() + Send + Sync + 'static,
{
    if let Ok(raw) = std::env::var("PROGNET_SCHED_REPLAY") {
        let schedule: Vec<u32> = raw
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect();
        if let Some(f) = replay(&schedule, body) {
            panic!("{}", f.render());
        }
        return;
    }
    let report = explore(Config::default(), body);
    if let Some(f) = report.failure {
        panic!("{}", f.render());
    }
}

/// Spawn a model thread. Must be called from inside a model run; the
/// returned handle joins through the scheduler (a blocking join is a
/// visible operation like any other).
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (state, parent) = current().expect("sched::spawn called outside a model run");
    let tid = state.register_thread(parent);
    let s2 = state.clone();
    let real = std::thread::Builder::new()
        .name(format!("prognet-model-{tid}"))
        .spawn(move || {
            CURRENT.with(|c| *c.borrow_mut() = Some((s2.clone(), tid)));
            let go = {
                let core = s2.lock_core();
                matches!(s2.wait_turn(core, tid), Turn::Go)
            };
            let result: std::thread::Result<T> = if go {
                std::panic::catch_unwind(AssertUnwindSafe(f))
            } else {
                Err(Box::new(ABORT_SENTINEL) as Box<dyn std::any::Any + Send>)
            };
            let msg = result.as_ref().err().map(|p| panic_text(p.as_ref()));
            s2.thread_finished(tid, msg);
            CURRENT.with(|c| *c.borrow_mut() = None);
            result
        })
        .expect("spawn model thread");
    JoinHandle { real, tid }
}

/// Handle to a model thread (see [`spawn`]).
pub struct JoinHandle<T> {
    real: std::thread::JoinHandle<std::thread::Result<T>>,
    tid: usize,
}

impl<T> JoinHandle<T> {
    /// Wait for the thread through the scheduler, then collect its
    /// result (the panic payload, if it panicked).
    pub fn join(self) -> std::thread::Result<T> {
        let (state, me) = current().expect("JoinHandle::join called outside a model run");
        state.join_thread(me, self.tid);
        self.real.join().and_then(|r| r)
    }
}

/// Record a scheduling point for the calling model thread (no-op
/// outside a model). `res` labels the state being touched.
pub fn point(op: &'static str, res: usize) {
    if let Some((state, tid)) = current() {
        state.point(tid, op, res);
    }
}

/// Acquire the model-level lock `res` (no-op outside a model). Pairs
/// with [`release`]; used directly by tests and by the mutex shim.
pub fn acquire(res: usize) {
    if let Some((state, tid)) = current() {
        state.acquire_lock(tid, res);
    }
}

/// Release the model-level lock `res` (no-op outside a model).
pub fn release(res: usize) {
    if let Some((state, tid)) = current() {
        state.release_lock(tid, res);
    }
}

/// Sleep: virtual inside a model, real outside.
pub fn sleep(dur: Duration) {
    match current() {
        Some((state, tid)) => state.sleep(tid, dur),
        None => std::thread::sleep(dur),
    }
}

/// The model's virtual clock (None outside a model run).
pub fn virtual_now() -> Option<Instant> {
    current().map(|(state, _)| state.virtual_now())
}

// ---------------------------------------------------------------------------
// Scheduler core
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Wait {
    Lock(usize),
    Read(usize),
    Write(usize),
    Condvar(usize),
    CondvarTimed { cv: usize, deadline_ns: u64 },
    Sleep { until_ns: u64 },
    Join(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    Runnable,
    Blocked(Wait),
    Finished,
}

struct ThreadState {
    status: Status,
    /// Set when a timed condvar wait was ended by the clock rather than
    /// a notify; consumed by the shim's `wait_timeout`.
    timed_out: bool,
}

impl ThreadState {
    fn new() -> Self {
        Self {
            status: Status::Runnable,
            timed_out: false,
        }
    }
}

/// Logical ownership state of one lock or rwlock.
#[derive(Default)]
struct ResState {
    owner: Option<usize>,
    readers: usize,
}

#[derive(Debug, Clone, Copy)]
struct Choice {
    chosen: u32,
    options: u32,
}

struct SplitMix(u64);

impl SplitMix {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

fn mix_seed(base: u64, i: u64) -> u64 {
    SplitMix(base ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next()
}

struct Core {
    threads: Vec<ThreadState>,
    active: usize,
    res: HashMap<usize, ResState>,
    trace: Vec<TraceStep>,
    choices: Vec<Choice>,
    prefix: Vec<u32>,
    rng: Option<SplitMix>,
    preemptions: usize,
    max_preemptions: Option<usize>,
    max_steps: usize,
    steps: usize,
    now_ns: u64,
    abort: bool,
    failure: Option<String>,
    running: usize,
    done: bool,
}

enum Turn {
    Go,
    Abort,
}

/// Shared state of one model run: the baton (`core` + `cv`) every model
/// thread synchronizes through. Public for the shim/facade layer only —
/// not a stable API (hence hidden).
#[doc(hidden)]
pub struct ModelState {
    core: Mutex<Core>,
    cv: Condvar,
    base: Instant,
}

impl ModelState {
    fn new(cfg: &Config, prefix: Vec<u32>, seed: Option<u64>) -> Self {
        Self {
            core: Mutex::new(Core {
                threads: vec![ThreadState::new()],
                active: 0,
                res: HashMap::new(),
                trace: Vec::new(),
                choices: Vec::new(),
                prefix,
                rng: seed.map(SplitMix),
                preemptions: 0,
                max_preemptions: cfg.max_preemptions,
                max_steps: cfg.max_steps,
                steps: 0,
                now_ns: 0,
                abort: false,
                failure: None,
                running: 1,
                done: false,
            }),
            cv: Condvar::new(),
            base: Instant::now(),
        }
    }

    fn lock_core(&self) -> MutexGuard<'_, Core> {
        self.core.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The virtual clock of this run (monotonic, starts at run launch).
    pub fn virtual_now(&self) -> Instant {
        let ns = self.lock_core().now_ns;
        self.base + Duration::from_nanos(ns)
    }

    /// A scheduling point: record the upcoming operation, then let the
    /// strategy pick the next thread to run. Returns when the calling
    /// thread is scheduled again (possibly immediately).
    pub fn point(&self, tid: usize, op: &'static str, res: usize) {
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            abort_current_thread();
            return;
        }
        core.steps += 1;
        if core.steps > core.max_steps {
            let budget = core.max_steps;
            self.fail(
                &mut core,
                format!("livelock: step budget ({budget}) exceeded"),
            );
            drop(core);
            abort_current_thread();
            return;
        }
        core.trace.push(TraceStep { tid, op, res });
        self.reschedule(&mut core, tid);
        if let Turn::Abort = self.wait_turn(core, tid) {
            abort_current_thread();
        }
    }

    /// Blocking lock acquire: a schedule decision, then take the lock or
    /// park until a release makes it available.
    pub fn acquire_lock(&self, tid: usize, res: usize) {
        self.point(tid, "lock", res);
        loop {
            let mut core = self.lock_core();
            if core.abort {
                drop(core);
                abort_current_thread();
                return;
            }
            let st = core.res.entry(res).or_default();
            if st.owner.is_none() && st.readers == 0 {
                st.owner = Some(tid);
                return;
            }
            core.threads[tid].status = Status::Blocked(Wait::Lock(res));
            self.reschedule(&mut core, tid);
            if let Turn::Abort = self.wait_turn(core, tid) {
                abort_current_thread();
                return;
            }
        }
    }

    /// Lock release. During unwind/teardown the resource is freed
    /// without a scheduling point so other threads can drain.
    pub fn release_lock(&self, tid: usize, res: usize) {
        if !std::thread::panicking() {
            self.point(tid, "unlock", res);
        }
        let mut core = self.lock_core();
        if let Some(st) = core.res.get_mut(&res) {
            if st.owner == Some(tid) {
                st.owner = None;
            }
        }
        wake_lock_waiters(&mut core, res);
        self.cv.notify_all();
    }

    pub fn acquire_read(&self, tid: usize, res: usize) {
        self.point(tid, "rwlock.read", res);
        loop {
            let mut core = self.lock_core();
            if core.abort {
                drop(core);
                abort_current_thread();
                return;
            }
            let st = core.res.entry(res).or_default();
            if st.owner.is_none() {
                st.readers += 1;
                return;
            }
            core.threads[tid].status = Status::Blocked(Wait::Read(res));
            self.reschedule(&mut core, tid);
            if let Turn::Abort = self.wait_turn(core, tid) {
                abort_current_thread();
                return;
            }
        }
    }

    pub fn release_read(&self, tid: usize, res: usize) {
        if !std::thread::panicking() {
            self.point(tid, "rwlock.unread", res);
        }
        let mut core = self.lock_core();
        if let Some(st) = core.res.get_mut(&res) {
            st.readers = st.readers.saturating_sub(1);
        }
        wake_lock_waiters(&mut core, res);
        self.cv.notify_all();
    }

    pub fn acquire_write(&self, tid: usize, res: usize) {
        self.point(tid, "rwlock.write", res);
        loop {
            let mut core = self.lock_core();
            if core.abort {
                drop(core);
                abort_current_thread();
                return;
            }
            let st = core.res.entry(res).or_default();
            if st.owner.is_none() && st.readers == 0 {
                st.owner = Some(tid);
                return;
            }
            core.threads[tid].status = Status::Blocked(Wait::Write(res));
            self.reschedule(&mut core, tid);
            if let Turn::Abort = self.wait_turn(core, tid) {
                abort_current_thread();
                return;
            }
        }
    }

    pub fn release_write(&self, tid: usize, res: usize) {
        self.release_lock(tid, res);
    }

    /// Condvar wait: atomically release `mutex_res` and park on `cv_res`
    /// (with an optional virtual-time deadline). Returns whether the
    /// wait ended by timeout. The caller re-acquires the mutex.
    pub fn condvar_wait(
        &self,
        tid: usize,
        cv_res: usize,
        mutex_res: usize,
        timeout: Option<Duration>,
    ) -> bool {
        self.point(tid, "cv.wait", cv_res);
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            abort_current_thread();
            return false;
        }
        if let Some(st) = core.res.get_mut(&mutex_res) {
            if st.owner == Some(tid) {
                st.owner = None;
            }
        }
        wake_lock_waiters(&mut core, mutex_res);
        core.threads[tid].timed_out = false;
        core.threads[tid].status = match timeout {
            None => Status::Blocked(Wait::Condvar(cv_res)),
            Some(d) => Status::Blocked(Wait::CondvarTimed {
                cv: cv_res,
                deadline_ns: core.now_ns.saturating_add(duration_ns(d)),
            }),
        };
        self.reschedule(&mut core, tid);
        if let Turn::Abort = self.wait_turn(core, tid) {
            abort_current_thread();
            return false;
        }
        self.lock_core().threads[tid].timed_out
    }

    /// Condvar notify (one waiter — the lowest tid — or all).
    pub fn notify(&self, tid: usize, cv_res: usize, all: bool) {
        let op = if all { "cv.notify_all" } else { "cv.notify_one" };
        if !std::thread::panicking() {
            self.point(tid, op, cv_res);
        }
        let mut core = self.lock_core();
        for t in core.threads.iter_mut() {
            let waiting = match t.status {
                Status::Blocked(Wait::Condvar(c)) => c == cv_res,
                Status::Blocked(Wait::CondvarTimed { cv, .. }) => cv == cv_res,
                _ => false,
            };
            if waiting {
                t.timed_out = false;
                t.status = Status::Runnable;
                if !all {
                    break;
                }
            }
        }
        self.cv.notify_all();
    }

    /// Atomic access: one scheduling point; the shim then performs the
    /// real operation at `SeqCst`.
    pub fn atomic_op(&self, tid: usize, op: &'static str, res: usize) {
        self.point(tid, op, res);
    }

    /// Virtual-time sleep.
    pub fn sleep(&self, tid: usize, dur: Duration) {
        self.point(tid, "sleep", 0);
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            abort_current_thread();
            return;
        }
        let until_ns = core.now_ns.saturating_add(duration_ns(dur));
        core.threads[tid].status = Status::Blocked(Wait::Sleep { until_ns });
        self.reschedule(&mut core, tid);
        if let Turn::Abort = self.wait_turn(core, tid) {
            abort_current_thread();
        }
    }

    /// Register a thread spawned by `parent`; returns the new tid.
    pub fn register_thread(&self, parent: usize) -> usize {
        self.point(parent, "spawn", 0);
        let mut core = self.lock_core();
        let tid = core.threads.len();
        core.threads.push(ThreadState::new());
        core.running += 1;
        tid
    }

    /// Blocking join on `target`.
    pub fn join_thread(&self, tid: usize, target: usize) {
        self.point(tid, "join", target);
        loop {
            let mut core = self.lock_core();
            if core.abort {
                drop(core);
                abort_current_thread();
                return;
            }
            if core.threads[target].status == Status::Finished {
                return;
            }
            core.threads[tid].status = Status::Blocked(Wait::Join(target));
            self.reschedule(&mut core, tid);
            if let Turn::Abort = self.wait_turn(core, tid) {
                abort_current_thread();
                return;
            }
        }
    }

    /// A model thread is done (normally or by panic). Non-sentinel panic
    /// messages become the run's failure; the run completes when every
    /// thread has finished.
    pub fn thread_finished(&self, tid: usize, panic_msg: Option<String>) {
        let mut core = self.lock_core();
        core.threads[tid].status = Status::Finished;
        core.running -= 1;
        core.trace.push(TraceStep {
            tid,
            op: "exit",
            res: 0,
        });
        if let Some(msg) = panic_msg {
            if msg != ABORT_SENTINEL && core.failure.is_none() {
                core.failure = Some(msg);
                core.abort = true;
            }
        }
        for t in core.threads.iter_mut() {
            if t.status == Status::Blocked(Wait::Join(tid)) {
                t.status = Status::Runnable;
            }
        }
        if core.running == 0 {
            core.done = true;
            self.cv.notify_all();
            return;
        }
        if core.abort {
            self.cv.notify_all();
            return;
        }
        self.reschedule(&mut core, tid);
    }

    /// Pick the next active thread: consult the strategy over the
    /// runnable set, advancing virtual time when everyone is parked on a
    /// deadline, and declaring deadlock when no wake is possible.
    fn reschedule(&self, core: &mut Core, from: usize) {
        loop {
            if core.abort {
                self.cv.notify_all();
                return;
            }
            let runnable: Vec<usize> = core
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| t.status == Status::Runnable)
                .map(|(i, _)| i)
                .collect();
            if !runnable.is_empty() {
                let from_runnable = core
                    .threads
                    .get(from)
                    .is_some_and(|t| t.status == Status::Runnable);
                let bound_spent = core
                    .max_preemptions
                    .is_some_and(|b| core.preemptions >= b);
                // Once the preemption budget is spent, a runnable thread
                // keeps running until it blocks or exits (CHESS).
                let options: Vec<usize> = if from_runnable && bound_spent {
                    vec![from]
                } else {
                    runnable
                };
                let idx = choose(core, options.len() as u32) as usize;
                let next = options[idx];
                if from_runnable && next != from {
                    core.preemptions += 1;
                }
                core.active = next;
                self.cv.notify_all();
                return;
            }
            // Nobody runnable: jump the clock to the earliest deadline.
            let mut earliest: Option<u64> = None;
            for t in &core.threads {
                let due = match t.status {
                    Status::Blocked(Wait::Sleep { until_ns }) => Some(until_ns),
                    Status::Blocked(Wait::CondvarTimed { deadline_ns, .. }) => Some(deadline_ns),
                    _ => None,
                };
                if let Some(d) = due {
                    earliest = Some(earliest.map_or(d, |e| e.min(d)));
                }
            }
            match earliest {
                Some(ns) => {
                    core.now_ns = core.now_ns.max(ns);
                    let now = core.now_ns;
                    for t in core.threads.iter_mut() {
                        match t.status {
                            Status::Blocked(Wait::Sleep { until_ns }) if until_ns <= now => {
                                t.status = Status::Runnable;
                            }
                            Status::Blocked(Wait::CondvarTimed { deadline_ns, .. })
                                if deadline_ns <= now =>
                            {
                                t.timed_out = true;
                                t.status = Status::Runnable;
                            }
                            _ => {}
                        }
                    }
                    // Loop back to choose among the newly runnable.
                }
                None => {
                    if core.running == 0 {
                        return;
                    }
                    let msg = deadlock_message(core);
                    self.fail(core, msg);
                    return;
                }
            }
        }
    }

    /// Park until this thread holds the baton (or the run is aborting).
    /// Consumes (and on return releases) the core guard.
    fn wait_turn(&self, mut core: MutexGuard<'_, Core>, tid: usize) -> Turn {
        loop {
            if core.abort {
                return Turn::Abort;
            }
            if core.active == tid && core.threads[tid].status == Status::Runnable {
                return Turn::Go;
            }
            core = self.cv.wait(core).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn fail(&self, core: &mut Core, msg: String) {
        if core.failure.is_none() {
            core.failure = Some(msg);
        }
        core.abort = true;
        self.cv.notify_all();
    }
}

/// Wake every thread parked on lock/rwlock `res`; they re-contend when
/// scheduled.
fn wake_lock_waiters(core: &mut Core, res: usize) {
    for t in core.threads.iter_mut() {
        let waiting = matches!(
            t.status,
            Status::Blocked(Wait::Lock(r) | Wait::Read(r) | Wait::Write(r)) if r == res
        );
        if waiting {
            t.status = Status::Runnable;
        }
    }
}

/// Record and return one scheduling choice among `options` candidates.
fn choose(core: &mut Core, options: u32) -> u32 {
    if options <= 1 {
        return 0;
    }
    let depth = core.choices.len();
    let chosen = if depth < core.prefix.len() {
        core.prefix[depth].min(options - 1)
    } else {
        match &mut core.rng {
            Some(rng) => (rng.next() % options as u64) as u32,
            None => 0,
        }
    };
    core.choices.push(Choice { chosen, options });
    chosen
}

fn deadlock_message(core: &Core) -> String {
    use std::fmt::Write as _;
    let mut msg = String::from("deadlock: no runnable threads —");
    for (i, t) in core.threads.iter().enumerate() {
        let state = match t.status {
            Status::Runnable => continue,
            Status::Finished => continue,
            Status::Blocked(Wait::Lock(r)) => format!("lock r{r}"),
            Status::Blocked(Wait::Read(r)) => format!("rwlock.read r{r}"),
            Status::Blocked(Wait::Write(r)) => format!("rwlock.write r{r}"),
            Status::Blocked(Wait::Condvar(r)) => format!("condvar r{r}"),
            Status::Blocked(Wait::CondvarTimed { cv, .. }) => format!("condvar(timed) r{cv}"),
            Status::Blocked(Wait::Sleep { .. }) => "sleep".to_string(),
            Status::Blocked(Wait::Join(t)) => format!("join t{t}"),
        };
        let _ = write!(msg, " t{i} waits on {state};");
    }
    msg
}

fn duration_ns(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

fn abort_current_thread() {
    if !std::thread::panicking() {
        std::panic::panic_any(ABORT_SENTINEL);
    }
}

fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

struct RunOutcome {
    failure: Option<String>,
    choices: Vec<Choice>,
    trace: Vec<TraceStep>,
}

fn record(report: &mut Report, out: &RunOutcome) {
    report.schedules += 1;
    report
        .schedules_taken
        .push(out.choices.iter().map(|c| c.chosen).collect());
    report.trace_digests.push(trace_digest(&out.trace));
}

fn make_failure(message: String, out: &RunOutcome, seed: Option<u64>) -> Failure {
    Failure {
        message,
        schedule: out.choices.iter().map(|c| c.chosen).collect(),
        seed,
        trace: out.trace.clone(),
    }
}

/// FNV-1a over the trace with resource ids normalized to first-seen
/// order, so the digest is stable across runs and processes.
fn trace_digest(trace: &[TraceStep]) -> u64 {
    let mut labels: HashMap<usize, usize> = HashMap::new();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |h: &mut u64, b: u8| {
        *h = (*h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    };
    for s in trace {
        let n = labels.len();
        let label = *labels.entry(s.res).or_insert(n);
        for v in [s.tid as u64, label as u64] {
            for b in v.to_le_bytes() {
                mix(&mut h, b);
            }
        }
        for b in s.op.bytes() {
            mix(&mut h, b);
        }
    }
    h
}

/// Model-thread panics are expected during exploration (that is how
/// failing schedules surface); suppress their default stderr backtrace
/// spam once per process, leaving every other thread's hook intact.
fn install_quiet_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let in_model = std::thread::current()
                .name()
                .is_some_and(|n| n.starts_with("prognet-model-"));
            if !in_model {
                prev(info);
            }
        }));
    });
}

fn run_once<F>(cfg: &Config, prefix: Vec<u32>, seed: Option<u64>, body: Arc<F>) -> RunOutcome
where
    F: Fn() + Send + Sync + 'static,
{
    install_quiet_hook();
    let state = Arc::new(ModelState::new(cfg, prefix, seed));
    let s2 = state.clone();
    let handle = std::thread::Builder::new()
        .name("prognet-model-0".to_string())
        .spawn(move || {
            CURRENT.with(|c| *c.borrow_mut() = Some((s2.clone(), 0)));
            let go = {
                let core = s2.lock_core();
                matches!(s2.wait_turn(core, 0), Turn::Go)
            };
            let result: std::thread::Result<()> = if go {
                std::panic::catch_unwind(AssertUnwindSafe(|| body()))
            } else {
                Err(Box::new(ABORT_SENTINEL) as Box<dyn std::any::Any + Send>)
            };
            let msg = result.as_ref().err().map(|p| panic_text(p.as_ref()));
            s2.thread_finished(0, msg);
            CURRENT.with(|c| *c.borrow_mut() = None);
        })
        .expect("spawn model main thread");

    let deadline = Instant::now() + Duration::from_secs(120);
    let mut core = state.lock_core();
    while !core.done {
        let (g, _) = state
            .cv
            .wait_timeout(core, Duration::from_millis(500))
            .unwrap_or_else(|p| p.into_inner());
        core = g;
        if !core.done && Instant::now() >= deadline {
            panic!("model run wedged: no completion within 120s (scheduler bug?)");
        }
    }
    let out = RunOutcome {
        failure: core.failure.clone(),
        choices: core.choices.clone(),
        trace: core.trace.clone(),
    };
    drop(core);
    let _ = handle.join();
    out
}

// ---------------------------------------------------------------------------
// Tests (normal builds too: the scheduler itself is always compiled)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn cfg(iters: usize) -> Config {
        Config {
            max_iterations: iters,
            ..Config::default()
        }
    }

    #[test]
    fn single_thread_exhausts_in_one_schedule() {
        let r = explore(cfg(100), || {
            point("a", 1);
            point("b", 2);
        });
        assert!(r.failure.is_none());
        assert_eq!(r.schedules, 1);
        assert!(r.exhausted);
    }

    /// The canonical non-atomic read-modify-write: two threads each do
    /// load-then-store with a scheduling point between — the checker
    /// must find the interleaving where one update is lost.
    fn lost_update_body() {
        let c = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let c = c.clone();
                spawn(move || {
                    point("load", 1);
                    let v = c.load(Ordering::SeqCst);
                    point("store", 1);
                    c.store(v + 1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.load(Ordering::SeqCst), 2, "lost update");
    }

    #[test]
    fn exhaustive_finds_lost_update_and_replays_it() {
        let r = explore(cfg(5000), lost_update_body);
        let f = r.failure.expect("exhaustive search must find the race");
        assert!(f.message.contains("lost update"), "{}", f.message);
        assert!(!f.trace.is_empty());
        let rendered = f.render();
        assert!(rendered.contains("schedule:"), "{rendered}");
        // The recorded schedule is a faithful reproduction.
        let again = replay(&f.schedule, lost_update_body).expect("replay must fail identically");
        assert_eq!(again.message, f.message);
    }

    #[test]
    fn deadlock_is_detected_with_wait_states() {
        let r = explore(cfg(5000), || {
            let t1 = spawn(|| {
                acquire(101);
                point("t1-holds-a", 101);
                acquire(102);
                release(102);
                release(101);
            });
            acquire(102);
            point("t0-holds-b", 102);
            acquire(101);
            release(101);
            release(102);
            let _ = t1.join();
        });
        let f = r.failure.expect("lock-order inversion must deadlock");
        assert!(f.message.contains("deadlock"), "{}", f.message);
        assert!(f.message.contains("waits on"), "{}", f.message);
    }

    fn race_free_body() {
        let c = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let c = c.clone();
                spawn(move || {
                    point("add", 7);
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn race_free_body_passes_exhaustively() {
        let r = explore(cfg(5000), race_free_body);
        assert!(r.failure.is_none(), "{:?}", r.failure.map(|f| f.message));
        assert!(r.exhausted, "small space must exhaust");
        assert!(r.schedules > 1, "must explore more than one interleaving");
    }

    #[test]
    fn same_seed_same_schedules_and_traces() {
        let c = Config {
            strategy: Strategy::Random,
            max_iterations: 40,
            seed: 0xC0FF_EE00,
            ..Config::default()
        };
        let a = explore(c.clone(), race_free_body);
        let b = explore(c, race_free_body);
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.schedules_taken, b.schedules_taken);
        assert_eq!(a.trace_digests, b.trace_digests);
    }

    #[test]
    fn different_seeds_reach_different_schedules() {
        let mk = |seed| Config {
            strategy: Strategy::Random,
            max_iterations: 40,
            seed,
            ..Config::default()
        };
        let a = explore(mk(1), race_free_body);
        let b = explore(mk(2), race_free_body);
        assert_ne!(
            a.schedules_taken, b.schedules_taken,
            "distinct seeds should explore distinct schedule sequences"
        );
    }

    #[test]
    fn virtual_time_advances_without_real_sleep() {
        let t0 = Instant::now();
        let r = explore(cfg(100), || {
            let before = virtual_now().unwrap();
            sleep(Duration::from_secs(30));
            let after = virtual_now().unwrap();
            assert!(after - before >= Duration::from_secs(30), "clock must jump");
        });
        assert!(r.failure.is_none(), "{:?}", r.failure.map(|f| f.message));
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "virtual sleep must not consume real time"
        );
    }

    #[test]
    fn sleepers_wake_in_deadline_order() {
        let r = explore(cfg(500), || {
            let order = Arc::new(AtomicUsize::new(0));
            let o1 = order.clone();
            let slow = spawn(move || {
                sleep(Duration::from_millis(20));
                // both sleepers parked before either deadline: the
                // 10ms sleeper must have woken first
                assert_eq!(o1.fetch_add(1, Ordering::SeqCst), 1, "woke before 10ms sleeper");
            });
            let o2 = order.clone();
            let fast = spawn(move || {
                sleep(Duration::from_millis(10));
                o2.fetch_add(1, Ordering::SeqCst);
            });
            slow.join().unwrap();
            fast.join().unwrap();
        });
        assert!(r.failure.is_none(), "{:?}", r.failure.map(|f| f.message));
    }

    #[test]
    fn step_budget_catches_livelock() {
        let c = Config {
            max_steps: 200,
            max_iterations: 5,
            ..Config::default()
        };
        let r = explore(c, || {
            for _ in 0..u64::MAX {
                point("spin", 9);
            }
        });
        let f = r.failure.expect("unbounded spin must trip the budget");
        assert!(f.message.contains("livelock"), "{}", f.message);
    }

    #[test]
    fn outside_model_ops_are_noops() {
        assert!(!in_model());
        point("noop", 0);
        acquire(1);
        release(1);
        assert!(virtual_now().is_none());
    }
}
