//! Instrumented drop-in replacements for `std::sync` primitives, wired
//! to the deterministic scheduler in [`super::sched`].
//!
//! Compiled only under `--cfg prognet_check`, and reached only through
//! the [`crate::util::sync`] facade. Every type is dual-mode:
//!
//! - **Inside a model run** (the calling thread has a scheduler handle
//!   in TLS): operations become scheduling points; blocking is logical
//!   (the scheduler parks the thread) rather than OS-level, so the
//!   checker controls every interleaving, detects deadlocks, and runs
//!   timeouts on virtual time.
//! - **Outside a model run**: operations defer to the wrapped std
//!   primitive, so the rest of the test suite behaves normally even
//!   when built with `--cfg prognet_check`.
//!
//! Modeled semantics (see the module docs on `sched` for rationale):
//! atomics are sequentially consistent regardless of requested ordering;
//! condvars have no spurious wakeups; `notify_one` wakes the lowest
//! waiting thread id. A lock/condvar must be used either entirely inside
//! models or entirely outside — mixing both on one object is unsupported.

#![forbid(unsafe_code)]

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError, TryLockError};
use std::time::Duration;

use super::sched;

/// Result of a timed condvar wait (mirrors `std::sync::WaitTimeoutResult`,
/// which has no public constructor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

/// Scheduler-aware mutex. Lock ownership is tracked logically by the
/// model; the inner std mutex still guards the data itself (so the
/// borrow rules and poisoning behave exactly like std).
pub struct Mutex<T> {
    res: usize,
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Self {
            res: sched::new_resource_id(),
            inner: std::sync::Mutex::new(value),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match sched::current() {
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard {
                    lock: self,
                    inner: Some(g),
                    model: false,
                }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    model: false,
                })),
            },
            Some((state, tid)) => {
                state.acquire_lock(tid, self.res);
                // Logical ownership is ours; the std mutex can only be
                // transiently contended (an aborting run unwinding, or a
                // non-model thread misusing a model lock), so spin.
                loop {
                    match self.inner.try_lock() {
                        Ok(g) => {
                            return Ok(MutexGuard {
                                lock: self,
                                inner: Some(g),
                                model: true,
                            })
                        }
                        Err(TryLockError::Poisoned(p)) => {
                            return Err(PoisonError::new(MutexGuard {
                                lock: self,
                                inner: Some(p.into_inner()),
                                model: true,
                            }))
                        }
                        Err(TryLockError::WouldBlock) => std::thread::yield_now(),
                    }
                }
            }
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    model: bool,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the std mutex before the logical release: when the
        // scheduler hands the lock to a waiter, the data is available.
        self.inner.take();
        if self.model {
            if let Some((state, tid)) = sched::current() {
                state.release_lock(tid, self.lock.res);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

pub struct Condvar {
    res: usize,
    inner: std::sync::Condvar,
}

impl Condvar {
    pub fn new() -> Self {
        Self {
            res: sched::new_resource_id(),
            inner: std::sync::Condvar::new(),
        }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        if guard.model {
            let (state, tid) = sched::current().expect("model guard on non-model thread");
            let lock = guard.lock;
            guard.inner.take();
            guard.model = false; // neutralize Drop's logical release
            drop(guard);
            state.condvar_wait(tid, self.res, lock.res, None);
            lock.lock()
        } else {
            let lock = guard.lock;
            let std_guard = guard.inner.take().expect("guard already released");
            drop(guard);
            match self.inner.wait(std_guard) {
                Ok(g) => Ok(MutexGuard {
                    lock,
                    inner: Some(g),
                    model: false,
                }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock,
                    inner: Some(p.into_inner()),
                    model: false,
                })),
            }
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if guard.model {
            let (state, tid) = sched::current().expect("model guard on non-model thread");
            let lock = guard.lock;
            guard.inner.take();
            guard.model = false;
            drop(guard);
            let timed_out = state.condvar_wait(tid, self.res, lock.res, Some(dur));
            match lock.lock() {
                Ok(g) => Ok((g, WaitTimeoutResult(timed_out))),
                Err(p) => Err(PoisonError::new((
                    p.into_inner(),
                    WaitTimeoutResult(timed_out),
                ))),
            }
        } else {
            let lock = guard.lock;
            let std_guard = guard.inner.take().expect("guard already released");
            drop(guard);
            match self.inner.wait_timeout(std_guard, dur) {
                Ok((g, t)) => Ok((
                    MutexGuard {
                        lock,
                        inner: Some(g),
                        model: false,
                    },
                    WaitTimeoutResult(t.timed_out()),
                )),
                Err(p) => {
                    let (g, t) = p.into_inner();
                    Err(PoisonError::new((
                        MutexGuard {
                            lock,
                            inner: Some(g),
                            model: false,
                        },
                        WaitTimeoutResult(t.timed_out()),
                    )))
                }
            }
        }
    }

    pub fn notify_one(&self) {
        if let Some((state, tid)) = sched::current() {
            state.notify(tid, self.res, false);
        }
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        if let Some((state, tid)) = sched::current() {
            state.notify(tid, self.res, true);
        }
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// RwLock
// ---------------------------------------------------------------------------

/// Scheduler-aware reader-writer lock with true shared/exclusive
/// semantics in the model (readers overlap; a writer excludes all).
pub struct RwLock<T> {
    res: usize,
    inner: std::sync::RwLock<T>,
}

impl<T> RwLock<T> {
    pub fn new(value: T) -> Self {
        Self {
            res: sched::new_resource_id(),
            inner: std::sync::RwLock::new(value),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        match sched::current() {
            None => match self.inner.read() {
                Ok(g) => Ok(RwLockReadGuard {
                    lock: self,
                    inner: Some(g),
                    model: false,
                }),
                Err(p) => Err(PoisonError::new(RwLockReadGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    model: false,
                })),
            },
            Some((state, tid)) => {
                state.acquire_read(tid, self.res);
                loop {
                    match self.inner.try_read() {
                        Ok(g) => {
                            return Ok(RwLockReadGuard {
                                lock: self,
                                inner: Some(g),
                                model: true,
                            })
                        }
                        Err(TryLockError::Poisoned(p)) => {
                            return Err(PoisonError::new(RwLockReadGuard {
                                lock: self,
                                inner: Some(p.into_inner()),
                                model: true,
                            }))
                        }
                        Err(TryLockError::WouldBlock) => std::thread::yield_now(),
                    }
                }
            }
        }
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        match sched::current() {
            None => match self.inner.write() {
                Ok(g) => Ok(RwLockWriteGuard {
                    lock: self,
                    inner: Some(g),
                    model: false,
                }),
                Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    model: false,
                })),
            },
            Some((state, tid)) => {
                state.acquire_write(tid, self.res);
                loop {
                    match self.inner.try_write() {
                        Ok(g) => {
                            return Ok(RwLockWriteGuard {
                                lock: self,
                                inner: Some(g),
                                model: true,
                            })
                        }
                        Err(TryLockError::Poisoned(p)) => {
                            return Err(PoisonError::new(RwLockWriteGuard {
                                lock: self,
                                inner: Some(p.into_inner()),
                                model: true,
                            }))
                        }
                        Err(TryLockError::WouldBlock) => std::thread::yield_now(),
                    }
                }
            }
        }
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T: fmt::Debug> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

pub struct RwLockReadGuard<'a, T> {
    lock: &'a RwLock<T>,
    inner: Option<std::sync::RwLockReadGuard<'a, T>>,
    model: bool,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        self.inner.take();
        if self.model {
            if let Some((state, tid)) = sched::current() {
                state.release_read(tid, self.lock.res);
            }
        }
    }
}

pub struct RwLockWriteGuard<'a, T> {
    lock: &'a RwLock<T>,
    inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
    model: bool,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        self.inner.take();
        if self.model {
            if let Some((state, tid)) = sched::current() {
                state.release_write(tid, self.lock.res);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

pub use std::sync::atomic::Ordering;

macro_rules! int_atomic {
    ($name:ident, $std:ty, $prim:ty) => {
        /// Scheduler-aware atomic: every access is a scheduling point
        /// inside a model and executes at `SeqCst` (the model checker
        /// verifies interleavings, not weak-memory orderings).
        pub struct $name {
            inner: $std,
        }

        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self {
                    inner: <$std>::new(v),
                }
            }

            fn res(&self) -> usize {
                self as *const Self as usize
            }

            pub fn load(&self, order: Ordering) -> $prim {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.load", self.res());
                    self.inner.load(Ordering::SeqCst)
                } else {
                    self.inner.load(order)
                }
            }

            pub fn store(&self, v: $prim, order: Ordering) {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.store", self.res());
                    self.inner.store(v, Ordering::SeqCst)
                } else {
                    self.inner.store(v, order)
                }
            }

            pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.rmw", self.res());
                    self.inner.swap(v, Ordering::SeqCst)
                } else {
                    self.inner.swap(v, order)
                }
            }

            pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.rmw", self.res());
                    self.inner.fetch_add(v, Ordering::SeqCst)
                } else {
                    self.inner.fetch_add(v, order)
                }
            }

            pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.rmw", self.res());
                    self.inner.fetch_sub(v, Ordering::SeqCst)
                } else {
                    self.inner.fetch_sub(v, order)
                }
            }

            pub fn fetch_max(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.rmw", self.res());
                    self.inner.fetch_max(v, Ordering::SeqCst)
                } else {
                    self.inner.fetch_max(v, order)
                }
            }

            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                _success: Ordering,
                _failure: Ordering,
            ) -> Result<$prim, $prim> {
                if let Some((state, tid)) = sched::current() {
                    state.atomic_op(tid, "atomic.rmw", self.res());
                    self.inner
                        .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
                } else {
                    self.inner
                        .compare_exchange(current, new, _success, _failure)
                }
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new(<$prim>::default())
            }
        }

        impl fmt::Debug for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                self.inner.fmt(f)
            }
        }
    };
}

int_atomic!(AtomicU8, std::sync::atomic::AtomicU8, u8);
int_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

/// Scheduler-aware `AtomicBool` (see the int atomics above).
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self {
            inner: std::sync::atomic::AtomicBool::new(v),
        }
    }

    fn res(&self) -> usize {
        self as *const Self as usize
    }

    pub fn load(&self, order: Ordering) -> bool {
        if let Some((state, tid)) = sched::current() {
            state.atomic_op(tid, "atomic.load", self.res());
            self.inner.load(Ordering::SeqCst)
        } else {
            self.inner.load(order)
        }
    }

    pub fn store(&self, v: bool, order: Ordering) {
        if let Some((state, tid)) = sched::current() {
            state.atomic_op(tid, "atomic.store", self.res());
            self.inner.store(v, Ordering::SeqCst)
        } else {
            self.inner.store(v, order)
        }
    }

    pub fn swap(&self, v: bool, order: Ordering) -> bool {
        if let Some((state, tid)) = sched::current() {
            state.atomic_op(tid, "atomic.rmw", self.res());
            self.inner.swap(v, Ordering::SeqCst)
        } else {
            self.inner.swap(v, order)
        }
    }

    pub fn fetch_or(&self, v: bool, order: Ordering) -> bool {
        if let Some((state, tid)) = sched::current() {
            state.atomic_op(tid, "atomic.rmw", self.res());
            self.inner.fetch_or(v, Ordering::SeqCst)
        } else {
            self.inner.fetch_or(v, order)
        }
    }

    pub fn fetch_and(&self, v: bool, order: Ordering) -> bool {
        if let Some((state, tid)) = sched::current() {
            state.atomic_op(tid, "atomic.rmw", self.res());
            self.inner.fetch_and(v, Ordering::SeqCst)
        } else {
            self.inner.fetch_and(v, order)
        }
    }

    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        if let Some((state, tid)) = sched::current() {
            state.atomic_op(tid, "atomic.rmw", self.res());
            self.inner
                .compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
        } else {
            self.inner.compare_exchange(current, new, success, failure)
        }
    }
}

impl Default for AtomicBool {
    fn default() -> Self {
        Self::new(false)
    }
}

impl fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}
