//! Concurrency correctness layer: deterministic model checking for the
//! crate's hand-rolled synchronization protocols.
//!
//! Two halves:
//!
//! - [`sched`] — a loom-style deterministic scheduler that serializes
//!   model threads onto a baton, explores interleavings (exhaustive DFS
//!   with bounded preemptions, or seeded random sampling), runs timeouts
//!   on virtual time, detects deadlocks and livelocks, and prints
//!   replayable failing schedules. Always compiled, so the checker's own
//!   unit tests run in normal builds.
//! - [`shim`] — instrumented `Mutex`/`Condvar`/`RwLock`/atomic
//!   replacements that report every operation to the scheduler. Compiled
//!   only under `--cfg prognet_check` and reached through the
//!   [`crate::util::sync`] facade, which re-exports plain `std::sync` in
//!   normal builds (zero overhead, zero behavior change).
//!
//! The schedule-exploration suite for the crate's real protocols lives
//! in `tests/schedules.rs` and runs under
//! `RUSTFLAGS='--cfg prognet_check' cargo test`. Design notes, the lint
//! rule catalog and replay instructions: `rust/docs/ANALYSIS.md`.

#![forbid(unsafe_code)]

pub mod sched;

#[cfg(prognet_check)]
pub mod shim;
