//! Per-model execution session: manifest-level validation in front of a
//! backend-compiled model, plus the hot-swappable [`ApproxModel`] handle
//! that upgrades in place as progressive stages land.
//!
//! A [`ModelSession`] binds one [`ModelManifest`] to one
//! [`CompiledModel`](super::CompiledModel) and is what every consumer —
//! the progressive client, the coordinator's batcher, the eval harness —
//! holds to run inference. The session validates buffer sizes against the
//! manifest; batching/padding strategy is the backend's business.
//!
//! An [`ApproxModel`] pairs a session with a versioned weight cell: the
//! progressive client publishes each stage's reconstruction into it, and
//! every reader (the coordinator's batcher, an application thread) infers
//! against an atomic snapshot — so mid-download serving always uses the
//! newest *complete* stage, and an in-flight batch keeps the weights it
//! started with.

#![forbid(unsafe_code)]

use crate::util::sync::{Arc, RwLock};

use anyhow::Result;

use super::backend::CompiledModel;
use super::engine::Engine;
use super::ops;
use crate::models::ModelManifest;

/// Inference output: `dim` values per sample.
#[derive(Debug, Clone)]
pub struct InferOutput {
    /// `n * dim` values, row-major.
    pub data: Vec<f32>,
    /// Values per sample (classes, +4 box coordinates for detection).
    pub dim: usize,
}

impl InferOutput {
    /// Number of samples in this output.
    pub fn n(&self) -> usize {
        self.data.len() / self.dim
    }

    /// The `i`-th sample's output row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Argmax over the first `classes` entries of each row.
    pub fn argmax_class(&self, i: usize, classes: usize) -> usize {
        let row = &self.row(i)[..classes];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap()
    }

    /// Softmax over the first `classes` logits of row `i` — class
    /// probabilities of one sample.
    pub fn probabilities(&self, i: usize, classes: usize) -> Vec<f32> {
        let mut p = self.row(i)[..classes].to_vec();
        ops::softmax(&mut p);
        p
    }
}

/// A model compiled by the engine's backend, ready for per-stage
/// inference.
pub struct ModelSession {
    manifest: ModelManifest,
    model: Arc<dyn CompiledModel>,
}

impl ModelSession {
    /// Compile every executable variant the model's artifacts provide
    /// (backends without artifacts, like the reference interpreter,
    /// derive the graph from the manifest instead).
    pub fn load(engine: &Engine, manifest: &ModelManifest) -> Result<Self> {
        Ok(Self {
            manifest: manifest.clone(),
            model: engine.compile(manifest, &[])?,
        })
    }

    /// Compile only specific batch sizes (faster startup for demos on
    /// artifact-compiling backends; a no-op hint for the interpreter).
    pub fn load_batches(
        engine: &Engine,
        manifest: &ModelManifest,
        batches: &[usize],
    ) -> Result<Self> {
        Ok(Self {
            manifest: manifest.clone(),
            model: engine.compile(manifest, batches)?,
        })
    }

    /// The manifest this session was compiled from.
    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    /// Run `n` samples through the float-weights forward path.
    ///
    /// `images` is `n * input_numel` floats; `weights` the flat vector
    /// (any progressive reconstruction).
    pub fn infer(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<InferOutput> {
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(
            weights.len() == self.manifest.param_count,
            "weights size mismatch"
        );
        let dim = self.manifest.output_dim();
        let data = self.model.execute(images, n, weights)?;
        anyhow::ensure!(data.len() == n * dim, "unexpected output size");
        Ok(InferOutput { data, dim })
    }

    /// Fused path: quantized codes in, Eq. 5 dequantization inside the
    /// backend (the PJRT `qfwd` executable's Pallas dequant kernel, or
    /// the interpreter's built-in dequant).
    pub fn infer_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<InferOutput> {
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(
            qflat.len() == self.manifest.param_count,
            "qflat size mismatch"
        );
        let dim = self.manifest.output_dim();
        let data = self.model.execute_quantized(images, n, qflat, cum_bits)?;
        anyhow::ensure!(data.len() == n * dim, "unexpected output size");
        Ok(InferOutput { data, dim })
    }

    /// The fused quantized path with a codes-version hint: backends that
    /// cache the dequantized weights under `(cum_bits, version)` (the
    /// reference interpreter) skip Eq. 5 when the pair repeats. Pair it
    /// with [`Assembler::codes_version`](crate::client::Assembler::codes_version);
    /// the version must change whenever `qflat` does.
    pub fn infer_quantized_versioned(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
        version: u64,
    ) -> Result<InferOutput> {
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(
            qflat.len() == self.manifest.param_count,
            "qflat size mismatch"
        );
        let dim = self.manifest.output_dim();
        let data = self
            .model
            .execute_quantized_versioned(images, n, qflat, cum_bits, version)?;
        anyhow::ensure!(data.len() == n * dim, "unexpected output size");
        Ok(InferOutput { data, dim })
    }

    /// Whether the backend compiled a fused quantized path for this model.
    pub fn has_qfwd(&self) -> bool {
        self.model.supports_quantized()
    }
}

impl Clone for ModelSession {
    /// Cheap handle clone: the compiled model is shared, not recompiled.
    fn clone(&self) -> Self {
        Self {
            manifest: self.manifest.clone(),
            model: self.model.clone(),
        }
    }
}

/// One published weight snapshot of an [`ApproxModel`].
#[derive(Clone)]
pub struct WeightsVersion {
    /// Flat dequantized weights (shared, immutable once published).
    pub flat: Arc<Vec<f32>>,
    /// Cumulative quantization bits of this snapshot (0 = none yet).
    pub cum_bits: u32,
    /// Monotonically increasing publish counter (0 = never published).
    pub version: u64,
}

/// Output of an [`ApproxModel`] inference, tagged with the exact weight
/// snapshot that produced it.
#[derive(Debug, Clone)]
pub struct ApproxOutput {
    /// The inference result.
    pub output: InferOutput,
    /// Cumulative bits of the weights used.
    pub cum_bits: u32,
    /// Publish counter of the weights used.
    pub version: u64,
}

/// A hot-swappable approximate model: a compiled [`ModelSession`] plus a
/// versioned weight cell that atomically upgrades as stages complete.
///
/// Cloning yields another handle onto the *same* cell, so a
/// `client::session::ProgressiveSession` can keep publishing refinements
/// while the coordinator's batcher serves requests from the other end —
/// the paper's mid-download serving, §III-C.
#[derive(Clone)]
pub struct ApproxModel {
    session: Arc<ModelSession>,
    cell: Arc<RwLock<WeightsVersion>>,
}

impl ApproxModel {
    /// Wrap a compiled session with an empty (version 0) weight cell.
    pub fn new(session: Arc<ModelSession>) -> Self {
        let n = session.manifest().param_count;
        Self {
            session,
            cell: Arc::new(RwLock::new(WeightsVersion {
                flat: Arc::new(vec![0f32; n]),
                cum_bits: 0,
                version: 0,
            })),
        }
    }

    /// Bind a session to an existing shared weight cell (the
    /// `coordinator::state::WeightStore` bridge).
    pub(crate) fn over(session: Arc<ModelSession>, cell: Arc<RwLock<WeightsVersion>>) -> Self {
        Self { session, cell }
    }

    /// The compiled session this handle executes on.
    pub fn session(&self) -> &Arc<ModelSession> {
        &self.session
    }

    /// The model manifest (shortcut for `session().manifest()`).
    pub fn manifest(&self) -> &ModelManifest {
        self.session.manifest()
    }

    /// Publish a refined reconstruction (copies the slice once) and
    /// return the new version. Panics if the parameter count changes.
    pub fn publish(&self, flat: &[f32], cum_bits: u32) -> u64 {
        let mut w = self.cell.write().unwrap();
        assert_eq!(flat.len(), w.flat.len(), "param count changed");
        w.flat = Arc::new(flat.to_vec());
        w.cum_bits = cum_bits;
        w.version += 1;
        w.version
    }

    /// Snapshot the current weights (cheap `Arc` clone; never blocks a
    /// concurrent publish for long).
    pub fn snapshot(&self) -> WeightsVersion {
        self.cell.read().unwrap().clone()
    }

    /// Has any stage been published yet?
    pub fn ready(&self) -> bool {
        self.version() > 0
    }

    /// Current publish counter.
    pub fn version(&self) -> u64 {
        self.cell.read().unwrap().version
    }

    /// Cumulative bits of the current snapshot (0 before the first
    /// publish).
    pub fn cum_bits(&self) -> u32 {
        self.cell.read().unwrap().cum_bits
    }

    /// Run `n` samples against the newest published snapshot. Errors
    /// before the first publish (no approximation exists yet).
    pub fn infer(&self, images: &[f32], n: usize) -> Result<ApproxOutput> {
        let snap = self.snapshot();
        anyhow::ensure!(
            snap.version > 0,
            "model '{}' has no published weights yet",
            self.session.manifest().name
        );
        let output = self.session.infer(images, n, &snap.flat)?;
        Ok(ApproxOutput {
            output,
            cum_bits: snap.cum_bits,
            version: snap.version,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fixture;

    fn session(tag: &str) -> (ModelSession, ModelManifest, Vec<f32>) {
        let reg = fixture::executable_models(tag).unwrap();
        let m = reg.get("dense3").unwrap().clone();
        let flat = m.load_weights().unwrap();
        let engine = Engine::reference();
        (ModelSession::load(&engine, &m).unwrap(), m, flat)
    }

    #[test]
    fn infer_shapes_over_sample_counts() {
        let (sess, m, flat) = session("sess-shapes");
        let ind = m.input_numel();
        for n in [1usize, 5, 33] {
            let images = vec![0.3f32; n * ind];
            let out = sess.infer(&images, n, &flat).unwrap();
            assert_eq!(out.n(), n);
            assert_eq!(out.dim, m.output_dim());
        }
    }

    #[test]
    fn infer_deterministic() {
        let (sess, m, flat) = session("sess-det");
        let images = vec![0.5f32; m.input_numel()];
        let a = sess.infer(&images, 1, &flat).unwrap();
        let b = sess.infer(&images, 1, &flat).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn bad_sizes_rejected() {
        let (sess, m, flat) = session("sess-bad");
        assert!(sess.infer(&[0.0; 3], 1, &flat).is_err());
        let images = vec![0f32; m.input_numel()];
        assert!(sess.infer(&images, 1, &flat[..4]).is_err());
        assert!(sess.infer_quantized(&images, 1, &[0u32; 4], 16).is_err());
    }

    #[test]
    fn approx_model_upgrades_in_place() {
        let (sess, m, flat) = session("sess-approx");
        let approx = ApproxModel::new(Arc::new(sess));
        let images = vec![0.5f32; m.input_numel()];
        // before any publish: not ready, inference refused
        assert!(!approx.ready());
        assert!(approx.infer(&images, 1).is_err());
        // publish a coarse snapshot through one handle …
        let handle = approx.clone();
        let v1 = handle.publish(&vec![0.0; flat.len()], 2);
        assert_eq!(v1, 1);
        // … the other handle sees it (shared cell)
        assert!(approx.ready());
        let a = approx.infer(&images, 1).unwrap();
        assert_eq!(a.cum_bits, 2);
        assert_eq!(a.version, 1);
        // upgrade to the real weights: output now matches a direct call
        let v2 = approx.publish(&flat, 16);
        assert_eq!(v2, 2);
        let b = approx.infer(&images, 1).unwrap();
        assert_eq!(b.cum_bits, 16);
        let direct = approx.session().infer(&images, 1, &flat).unwrap();
        assert_eq!(b.output.data, direct.data);
    }

    #[test]
    #[should_panic(expected = "param count changed")]
    fn approx_publish_wrong_size_panics() {
        let (sess, _m, _flat) = session("sess-approx-bad");
        let approx = ApproxModel::new(Arc::new(sess));
        approx.publish(&[0.0; 3], 2);
    }

    #[test]
    fn probabilities_normalize() {
        let (sess, m, flat) = session("sess-prob");
        let images = vec![0.7f32; m.input_numel()];
        let out = sess.infer(&images, 1, &flat).unwrap();
        let p = out.probabilities(0, m.classes);
        assert_eq!(p.len(), m.classes);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // argmax is preserved by softmax
        let argmax_p = p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax_p, out.argmax_class(0, m.classes));
    }
}
