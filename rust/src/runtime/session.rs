//! Per-model execution session: batching, padding, fwd/qfwd staging.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::engine::{literal_f32, literal_u32, Engine, Executable};
use crate::models::ModelManifest;
use crate::quant::{half_correction, QuantParams};

/// Inference output: `dim` values per sample.
#[derive(Debug, Clone)]
pub struct InferOutput {
    pub data: Vec<f32>,
    pub dim: usize,
}

impl InferOutput {
    pub fn n(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Argmax over the first `classes` entries of each row.
    pub fn argmax_class(&self, i: usize, classes: usize) -> usize {
        let row = &self.row(i)[..classes];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap()
    }
}

/// A model bound to compiled executables.
///
/// `fwd` variants take `(x, flat_weights)`; the [`ModelSession::infer`]
/// call picks the largest compiled batch ≤ n and loops/pads. The `qfwd`
/// variant runs the L1 Pallas dequant kernel inside the executable.
pub struct ModelSession {
    manifest: ModelManifest,
    fwd: BTreeMap<usize, Executable>,
    qfwd: BTreeMap<usize, Executable>,
}

impl ModelSession {
    /// Compile the model's fwd executables (and qfwd if present).
    pub fn load(engine: &Engine, manifest: &ModelManifest) -> Result<Self> {
        let mut fwd = BTreeMap::new();
        let mut qfwd = BTreeMap::new();
        for (key, _) in manifest.hlo.clone() {
            if let Some(b) = key.strip_prefix("fwd_b").and_then(|s| s.parse::<usize>().ok()) {
                fwd.insert(b, engine.compile_hlo_text(&manifest.hlo_path(&key)?)?);
            } else if let Some(b) = key
                .strip_prefix("qfwd_b")
                .and_then(|s| s.parse::<usize>().ok())
            {
                qfwd.insert(b, engine.compile_hlo_text(&manifest.hlo_path(&key)?)?);
            }
        }
        if fwd.is_empty() {
            bail!("{}: no fwd artifacts", manifest.name);
        }
        Ok(Self {
            manifest: manifest.clone(),
            fwd,
            qfwd,
        })
    }

    /// Load only specific batch sizes (faster startup for demos).
    pub fn load_batches(engine: &Engine, manifest: &ModelManifest, batches: &[usize]) -> Result<Self> {
        let mut fwd = BTreeMap::new();
        for &b in batches {
            let key = format!("fwd_b{b}");
            fwd.insert(b, engine.compile_hlo_text(&manifest.hlo_path(&key)?)?);
        }
        Ok(Self {
            manifest: manifest.clone(),
            fwd,
            qfwd: BTreeMap::new(),
        })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    fn input_dims(&self, batch: usize) -> Vec<i64> {
        let mut dims = vec![batch as i64];
        dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));
        dims
    }

    /// Pick the executable batch for `n` samples: the largest compiled
    /// batch ≤ n, or the smallest one if n is below all of them.
    fn pick_batch(map: &BTreeMap<usize, Executable>, n: usize) -> usize {
        let mut best = None;
        for &b in map.keys() {
            if b <= n {
                best = Some(b);
            }
        }
        best.unwrap_or_else(|| *map.keys().next().unwrap())
    }

    /// Run `n` samples through the float-weights forward path.
    ///
    /// `images` is `n * input_numel` floats; `weights` the flat vector
    /// (any progressive reconstruction). Handles batching + padding.
    pub fn infer(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<InferOutput> {
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(
            weights.len() == self.manifest.param_count,
            "weights size mismatch"
        );
        let dim = self.manifest.output_dim();
        let mut out = Vec::with_capacity(n * dim);
        let mut done = 0;
        let wlit_cache: Option<xla::Literal> = None;
        let mut wlit_cache = wlit_cache;
        let mut cached_batch = usize::MAX;
        while done < n {
            let batch = Self::pick_batch(&self.fwd, n - done);
            let exe = &self.fwd[&batch];
            let take = batch.min(n - done);
            let mut chunk = vec![0f32; batch * ind];
            chunk[..take * ind].copy_from_slice(&images[done * ind..(done + take) * ind]);
            let xlit = literal_f32(&chunk, &self.input_dims(batch))?;
            // weights literal is reusable across chunks of the same batch
            if cached_batch != batch || wlit_cache.is_none() {
                wlit_cache = Some(literal_f32(weights, &[weights.len() as i64])?);
                cached_batch = batch;
            }
            let res = exe.run_f32(&[xlit, wlit_cache.clone().unwrap()])?;
            anyhow::ensure!(res.len() == batch * dim, "unexpected output size");
            out.extend_from_slice(&res[..take * dim]);
            done += take;
        }
        Ok(InferOutput { data: out, dim })
    }

    /// Fused path: quantized codes in, Pallas dequant inside the HLO.
    pub fn infer_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<InferOutput> {
        if self.qfwd.is_empty() {
            bail!("{}: no qfwd artifacts compiled", self.manifest.name);
        }
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(qflat.len() == self.manifest.param_count, "qflat size mismatch");
        let k = self.manifest.k;
        let scales: Vec<f32> = self
            .manifest
            .tensors
            .iter()
            .map(|t| {
                QuantParams {
                    min: t.min,
                    max: t.max,
                    k,
                }
                .dequant_scale()
            })
            .collect();
        let los: Vec<f32> = self.manifest.tensors.iter().map(|t| t.min).collect();
        let half = [half_correction(k, cum_bits)];
        let dim = self.manifest.output_dim();
        let mut out = Vec::with_capacity(n * dim);
        let mut done = 0;
        while done < n {
            let batch = Self::pick_batch(&self.qfwd, n - done);
            let exe = &self.qfwd[&batch];
            let take = batch.min(n - done);
            let mut chunk = vec![0f32; batch * ind];
            chunk[..take * ind].copy_from_slice(&images[done * ind..(done + take) * ind]);
            let res = exe.run_f32(&[
                literal_f32(&chunk, &self.input_dims(batch))?,
                literal_u32(qflat, &[qflat.len() as i64])?,
                literal_f32(&scales, &[scales.len() as i64])?,
                literal_f32(&los, &[los.len() as i64])?,
                literal_f32(&half, &[1])?,
            ])?;
            anyhow::ensure!(res.len() == batch * dim, "unexpected output size");
            out.extend_from_slice(&res[..take * dim]);
            done += take;
        }
        Ok(InferOutput { data: out, dim })
    }

    pub fn has_qfwd(&self) -> bool {
        !self.qfwd.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn session(name: &str) -> Option<(ModelSession, ModelManifest)> {
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let engine = Engine::global().unwrap();
        let reg = Registry::open_default().unwrap();
        let m = reg.get(name).unwrap().clone();
        Some((ModelSession::load_batches(&engine, &m, &[1, 32]).unwrap(), m))
    }

    #[test]
    fn infer_shapes_and_padding() {
        let Some((sess, m)) = session("mlp") else { return };
        let w = m.load_weights().unwrap();
        let ind = m.input_numel();
        // n=5 forces batch-1 fallback or batch-32 padding paths
        for n in [1usize, 5, 33] {
            let images = vec![0.3f32; n * ind];
            let out = sess.infer(&images, n, &w).unwrap();
            assert_eq!(out.n(), n);
            assert_eq!(out.dim, 10);
        }
    }

    #[test]
    fn infer_deterministic() {
        let Some((sess, m)) = session("mlp") else { return };
        let w = m.load_weights().unwrap();
        let images = vec![0.5f32; m.input_numel()];
        let a = sess.infer(&images, 1, &w).unwrap();
        let b = sess.infer(&images, 1, &w).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn bad_sizes_rejected() {
        let Some((sess, m)) = session("mlp") else { return };
        let w = m.load_weights().unwrap();
        assert!(sess.infer(&[0.0; 10], 1, &w).is_err());
        let images = vec![0f32; m.input_numel()];
        assert!(sess.infer(&images, 1, &w[..100]).is_err());
    }
}
