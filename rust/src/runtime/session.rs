//! Per-model execution session: manifest-level validation in front of a
//! backend-compiled model.
//!
//! A [`ModelSession`] binds one [`ModelManifest`] to one
//! [`CompiledModel`](super::CompiledModel) and is what every consumer —
//! the progressive client, the coordinator's batcher, the eval harness —
//! holds to run inference. The session validates buffer sizes against the
//! manifest; batching/padding strategy is the backend's business.

use std::sync::Arc;

use anyhow::Result;

use super::backend::CompiledModel;
use super::engine::Engine;
use super::ops;
use crate::models::ModelManifest;

/// Inference output: `dim` values per sample.
#[derive(Debug, Clone)]
pub struct InferOutput {
    /// `n * dim` values, row-major.
    pub data: Vec<f32>,
    /// Values per sample (classes, +4 box coordinates for detection).
    pub dim: usize,
}

impl InferOutput {
    /// Number of samples in this output.
    pub fn n(&self) -> usize {
        self.data.len() / self.dim
    }

    /// The `i`-th sample's output row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Argmax over the first `classes` entries of each row.
    pub fn argmax_class(&self, i: usize, classes: usize) -> usize {
        let row = &self.row(i)[..classes];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap()
    }

    /// Softmax over the first `classes` logits of row `i` — class
    /// probabilities of one sample.
    pub fn probabilities(&self, i: usize, classes: usize) -> Vec<f32> {
        let mut p = self.row(i)[..classes].to_vec();
        ops::softmax(&mut p);
        p
    }
}

/// A model compiled by the engine's backend, ready for per-stage
/// inference.
pub struct ModelSession {
    manifest: ModelManifest,
    model: Arc<dyn CompiledModel>,
}

impl ModelSession {
    /// Compile every executable variant the model's artifacts provide
    /// (backends without artifacts, like the reference interpreter,
    /// derive the graph from the manifest instead).
    pub fn load(engine: &Engine, manifest: &ModelManifest) -> Result<Self> {
        Ok(Self {
            manifest: manifest.clone(),
            model: engine.compile(manifest, &[])?,
        })
    }

    /// Compile only specific batch sizes (faster startup for demos on
    /// artifact-compiling backends; a no-op hint for the interpreter).
    pub fn load_batches(
        engine: &Engine,
        manifest: &ModelManifest,
        batches: &[usize],
    ) -> Result<Self> {
        Ok(Self {
            manifest: manifest.clone(),
            model: engine.compile(manifest, batches)?,
        })
    }

    /// The manifest this session was compiled from.
    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    /// Run `n` samples through the float-weights forward path.
    ///
    /// `images` is `n * input_numel` floats; `weights` the flat vector
    /// (any progressive reconstruction).
    pub fn infer(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<InferOutput> {
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(
            weights.len() == self.manifest.param_count,
            "weights size mismatch"
        );
        let dim = self.manifest.output_dim();
        let data = self.model.execute(images, n, weights)?;
        anyhow::ensure!(data.len() == n * dim, "unexpected output size");
        Ok(InferOutput { data, dim })
    }

    /// Fused path: quantized codes in, Eq. 5 dequantization inside the
    /// backend (the PJRT `qfwd` executable's Pallas dequant kernel, or
    /// the interpreter's built-in dequant).
    pub fn infer_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<InferOutput> {
        let ind = self.manifest.input_numel();
        anyhow::ensure!(images.len() == n * ind, "image buffer size mismatch");
        anyhow::ensure!(
            qflat.len() == self.manifest.param_count,
            "qflat size mismatch"
        );
        let dim = self.manifest.output_dim();
        let data = self.model.execute_quantized(images, n, qflat, cum_bits)?;
        anyhow::ensure!(data.len() == n * dim, "unexpected output size");
        Ok(InferOutput { data, dim })
    }

    /// Whether the backend compiled a fused quantized path for this model.
    pub fn has_qfwd(&self) -> bool {
        self.model.supports_quantized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::fixture;

    fn session(tag: &str) -> (ModelSession, ModelManifest, Vec<f32>) {
        let reg = fixture::executable_models(tag).unwrap();
        let m = reg.get("dense3").unwrap().clone();
        let flat = m.load_weights().unwrap();
        let engine = Engine::reference();
        (ModelSession::load(&engine, &m).unwrap(), m, flat)
    }

    #[test]
    fn infer_shapes_over_sample_counts() {
        let (sess, m, flat) = session("sess-shapes");
        let ind = m.input_numel();
        for n in [1usize, 5, 33] {
            let images = vec![0.3f32; n * ind];
            let out = sess.infer(&images, n, &flat).unwrap();
            assert_eq!(out.n(), n);
            assert_eq!(out.dim, m.output_dim());
        }
    }

    #[test]
    fn infer_deterministic() {
        let (sess, m, flat) = session("sess-det");
        let images = vec![0.5f32; m.input_numel()];
        let a = sess.infer(&images, 1, &flat).unwrap();
        let b = sess.infer(&images, 1, &flat).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn bad_sizes_rejected() {
        let (sess, m, flat) = session("sess-bad");
        assert!(sess.infer(&[0.0; 3], 1, &flat).is_err());
        let images = vec![0f32; m.input_numel()];
        assert!(sess.infer(&images, 1, &flat[..4]).is_err());
        assert!(sess.infer_quantized(&images, 1, &[0u32; 4], 16).is_err());
    }

    #[test]
    fn probabilities_normalize() {
        let (sess, m, flat) = session("sess-prob");
        let images = vec![0.7f32; m.input_numel()];
        let out = sess.infer(&images, 1, &flat).unwrap();
        let p = out.probabilities(0, m.classes);
        assert_eq!(p.len(), m.classes);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // argmax is preserved by softmax
        let argmax_p = p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax_p, out.argmax_class(0, m.classes));
    }
}
