//! Backend selection and the process-wide [`Engine`] handle.
//!
//! An [`Engine`] is a cheap-to-clone handle on one [`Backend`] instance.
//! Which backend it wraps is decided once, in order of precedence:
//!
//! 1. an explicit constructor ([`Engine::reference`], `Engine::pjrt`),
//! 2. [`Engine::named`] with a CLI-style name (`--backend reference`),
//! 3. the `PROGNET_BACKEND` environment variable (`reference` | `pjrt`),
//! 4. the default: the pure-Rust reference interpreter, which works
//!    offline on any machine with no artifacts and no native deps.
//!
//! The `pjrt` backend is only present when the crate is built with the
//! `pjrt` cargo feature; selecting it in a default build is an error, not
//! a silent fallback.

#![forbid(unsafe_code)]

use crate::util::sync::Arc;

use anyhow::Result;

use super::backend::{Backend, CompiledModel};
use super::reference::ReferenceBackend;
use crate::models::ModelManifest;

/// Process-wide execution engine handle. Cheap to clone (shared
/// internally); compilation results are cached inside the backend.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
}

impl Engine {
    /// An engine over the pure-Rust reference interpreter (always
    /// available, no artifacts required).
    pub fn reference() -> Self {
        Self {
            backend: Arc::new(ReferenceBackend::new()),
        }
    }

    /// An engine over the XLA/PJRT CPU client (requires the `pjrt`
    /// cargo feature and the AOT HLO artifacts).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Self> {
        Ok(Self {
            backend: Arc::new(super::pjrt::PjrtBackend::cpu()?),
        })
    }

    /// Build an engine from a backend name (`"reference"`,
    /// `"reference-scalar"` or `"pjrt"`).
    pub fn named(name: &str) -> Result<Self> {
        match name {
            "reference" => Ok(Self::reference()),
            // the per-sample oracle interpreter — A/B baseline for the
            // batched fast path (benches/runtime.rs)
            "reference-scalar" => Ok(Self {
                backend: Arc::new(ReferenceBackend::scalar()),
            }),
            #[cfg(feature = "pjrt")]
            "pjrt" => Self::pjrt(),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => anyhow::bail!(
                "backend 'pjrt' is not compiled in; rebuild with `--features pjrt`"
            ),
            other => anyhow::bail!(
                "unknown backend '{other}' (have: reference, reference-scalar, pjrt)"
            ),
        }
    }

    /// Build an engine from `PROGNET_BACKEND`, defaulting to the
    /// reference interpreter when unset.
    pub fn from_env() -> Result<Self> {
        match std::env::var("PROGNET_BACKEND") {
            Ok(name) => Self::named(name.trim()),
            Err(_) => Ok(Self::reference()),
        }
    }

    /// Shared process-wide engine (lazily created via [`Engine::from_env`]).
    pub fn global() -> Result<Engine> {
        static GLOBAL: std::sync::OnceLock<Engine> = std::sync::OnceLock::new();
        if let Some(e) = GLOBAL.get() {
            return Ok(e.clone());
        }
        // Losing the set race must still hand back the winner's engine, or
        // concurrent first callers would hold distinct backend caches.
        let e = Engine::from_env()?;
        Ok(GLOBAL.get_or_init(|| e).clone())
    }

    /// Name of the backend this engine wraps.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Compile a model through the backend; an empty `batches` slice means
    /// "every batch size the artifacts provide" (see [`Backend::compile`]).
    pub fn compile(
        &self,
        manifest: &ModelManifest,
        batches: &[usize],
    ) -> Result<Arc<dyn CompiledModel>> {
        self.backend.compile(manifest, batches)
    }

    /// Number of compilation cache entries the backend currently holds.
    pub fn cached(&self) -> usize {
        self.backend.cached()
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("backend", &self.backend.name())
            .field("cached", &self.backend.cached())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_engine_always_constructs() {
        let e = Engine::reference();
        assert_eq!(e.backend_name(), "reference");
        assert_eq!(e.cached(), 0);
        let clone = e.clone();
        assert_eq!(clone.backend_name(), "reference");
    }

    #[test]
    fn named_selection() {
        assert_eq!(Engine::named("reference").unwrap().backend_name(), "reference");
        assert_eq!(
            Engine::named("reference-scalar").unwrap().backend_name(),
            "reference-scalar"
        );
        assert!(Engine::named("tpu-v9").is_err());
        #[cfg(not(feature = "pjrt"))]
        assert!(Engine::named("pjrt").is_err());
    }

    #[test]
    fn global_is_shared() {
        let a = Engine::global().unwrap();
        let b = Engine::global().unwrap();
        assert_eq!(a.backend_name(), b.backend_name());
        // both handles must wrap the same backend instance: a compile
        // through one is visible in the other's cache counter
        let reg = crate::testutil::fixture::executable_models("engine-global").unwrap();
        let m = reg.get("dense3").unwrap();
        a.compile(m, &[]).unwrap();
        assert!(b.cached() >= 1, "global engines hold separate backends");
    }
}
