//! PJRT CPU client wrapper with an HLO executable cache.
//!
//! The `xla` crate's handles are raw pointers (`!Send`); PJRT's CPU client
//! is internally synchronized, so we wrap everything in a `Mutex` and
//! assert `Send + Sync` on the wrapper. All executions in this process
//! share one client (one thread pool, one allocator).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

struct EngineInner {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, Arc<ExecutableInner>>,
}

// SAFETY: the PJRT CPU client is thread-safe for compile/execute; all
// access to the raw handles is serialized through the Engine mutex.
unsafe impl Send for EngineInner {}

struct ExecutableInner {
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for ExecutableInner {}
unsafe impl Sync for ExecutableInner {}

/// Process-wide PJRT engine. Cheap to clone (shared internally).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<Mutex<EngineInner>>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self {
            inner: Arc::new(Mutex::new(EngineInner {
                client,
                cache: HashMap::new(),
            })),
        })
    }

    /// Shared process-wide engine (lazily created).
    pub fn global() -> Result<Engine> {
        static GLOBAL: std::sync::OnceLock<Engine> = std::sync::OnceLock::new();
        if let Some(e) = GLOBAL.get() {
            return Ok(e.clone());
        }
        let e = Engine::cpu()?;
        let _ = GLOBAL.set(e.clone());
        Ok(e)
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(exe) = inner.cache.get(path) {
            return Ok(Executable {
                inner: exe.clone(),
                engine: self.inner.clone(),
            });
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        crate::log_debug!(
            "compiled {} in {:.2}s",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
        let arc = Arc::new(ExecutableInner { exe });
        inner.cache.insert(path.to_path_buf(), arc.clone());
        Ok(Executable {
            inner: arc,
            engine: self.inner.clone(),
        })
    }

    /// Number of cached executables.
    pub fn cached(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }
}

/// A compiled computation bound to the engine.
#[derive(Clone)]
pub struct Executable {
    inner: Arc<ExecutableInner>,
    engine: Arc<Mutex<EngineInner>>,
}

impl Executable {
    /// Execute with literal inputs; unwraps the 1-tuple output (aot.py
    /// lowers with `return_tuple=True`) and returns the flat f32 vector.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let lit = self.run_literal(inputs)?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Execute and return the raw output literal (un-tupled).
    pub fn run_literal(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        // Serialize access through the engine mutex: the CPU client is a
        // single shared thread pool anyway (1-core testbed).
        let _guard = self.engine.lock().unwrap();
        let result = self.inner.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }
}

/// Build a rank-N f32 literal from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {dims:?} wants {numel} elements, got {}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a rank-N u32 literal from a flat slice.
pub fn literal_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {dims:?} wants {numel} elements, got {}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_smoke_artifact_runs() {
        // artifacts/kernel_smoke.hlo.txt: f(q[2048] u32, scale, lo, half,
        // x[8,64]) = x @ dequant(q).reshape(64, 32); Pallas dequant +
        // Pallas matmul inside.
        if !crate::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::global().unwrap();
        let exe = engine
            .compile_hlo_text(&crate::artifacts_root().join("kernel_smoke.hlo.txt"))
            .unwrap();

        let q: Vec<u32> = (0..2048u32).map(|i| (i * 31) % 65536).collect();
        let scale = 1.0f32 / 65536.0;
        let lo = -0.5f32;
        let half = 0.5f32;
        let x: Vec<f32> = (0..8 * 64).map(|i| (i % 7) as f32 * 0.1).collect();

        let out = exe
            .run_f32(&[
                literal_u32(&q, &[2048]).unwrap(),
                literal_f32(&[scale], &[1]).unwrap(),
                literal_f32(&[lo], &[1]).unwrap(),
                literal_f32(&[half], &[1]).unwrap(),
                literal_f32(&x, &[8, 64]).unwrap(),
            ])
            .unwrap();
        assert_eq!(out.len(), 8 * 32);

        // oracle: dequant + matmul in rust
        let w: Vec<f32> = q.iter().map(|&v| (v as f32 + half) * scale + lo).collect();
        for i in 0..8 {
            for j in 0..32 {
                let mut acc = 0f32;
                for l in 0..64 {
                    acc += x[i * 64 + l] * w[l * 32 + j];
                }
                let got = out[i * 32 + j];
                assert!(
                    (acc - got).abs() < 1e-3,
                    "({i},{j}): {acc} vs {got}"
                );
            }
        }
    }

    #[test]
    fn compile_cache_hits() {
        if !crate::artifacts_available() {
            return;
        }
        let engine = Engine::global().unwrap();
        let path = crate::artifacts_root().join("kernel_smoke.hlo.txt");
        let n0 = engine.cached();
        let _a = engine.compile_hlo_text(&path).unwrap();
        let _b = engine.compile_hlo_text(&path).unwrap();
        assert!(engine.cached() >= 1 && engine.cached() <= n0 + 1);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_u32(&[1, 2, 3], &[2, 2]).is_err());
    }
}
