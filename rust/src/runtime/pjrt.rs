//! The XLA/PJRT backend (cargo feature `pjrt`).
//!
//! Wraps the `xla` crate's PJRT CPU client behind the [`Backend`] trait:
//! models are executed from the AOT HLO-text artifacts built by
//! `python/compile/` (jax ≥ 0.5 emits serialized protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids).
//!
//! The `xla` crate's handles are raw pointers (`!Send`); PJRT's CPU
//! client is internally synchronized, so everything is wrapped in a
//! `Mutex` and `Send + Sync` is asserted on the wrapper. All executions
//! in this process share one client (one thread pool, one allocator).
//!
//! Offline builds compile this module against the API-compatible stub
//! crate vendored at `rust/pjrt-stub/`; see `rust/README.md` for pointing
//! the dependency at a real `xla` checkout instead.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use crate::util::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{Backend, CompiledModel};
use crate::models::ModelManifest;
use crate::quant::{half_correction, QuantParams};

struct EngineInner {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, Arc<ExecutableInner>>,
}

// SAFETY: the PJRT CPU client is thread-safe for compile/execute; all
// access to the raw handles is serialized through the backend mutex.
unsafe impl Send for EngineInner {}

struct ExecutableInner {
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for ExecutableInner {}
unsafe impl Sync for ExecutableInner {}

/// The PJRT execution backend: one shared CPU client plus an HLO
/// executable cache keyed by artifact path.
pub struct PjrtBackend {
    inner: Arc<Mutex<EngineInner>>,
}

impl PjrtBackend {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self {
            inner: Arc::new(Mutex::new(EngineInner {
                client,
                cache: HashMap::new(),
            })),
        })
    }

    /// Load + compile an HLO text file (cached by path).
    fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(exe) = inner.cache.get(path) {
            return Ok(Executable {
                inner: exe.clone(),
                engine: self.inner.clone(),
            });
        }
        let t0 = Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 artifact path")?)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        crate::log_debug!(
            "compiled {} in {:.2}s",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
        let arc = Arc::new(ExecutableInner { exe });
        inner.cache.insert(path.to_path_buf(), arc.clone());
        Ok(Executable {
            inner: arc,
            engine: self.inner.clone(),
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(
        &self,
        manifest: &ModelManifest,
        batches: &[usize],
    ) -> Result<Arc<dyn CompiledModel>> {
        let mut fwd = BTreeMap::new();
        let mut qfwd = BTreeMap::new();
        if batches.is_empty() {
            // every artifact the manifest provides
            for (key, _) in manifest.hlo.clone() {
                if let Some(b) = key.strip_prefix("fwd_b").and_then(|s| s.parse::<usize>().ok()) {
                    fwd.insert(b, self.compile_hlo_text(&manifest.hlo_path(&key)?)?);
                } else if let Some(b) = key
                    .strip_prefix("qfwd_b")
                    .and_then(|s| s.parse::<usize>().ok())
                {
                    qfwd.insert(b, self.compile_hlo_text(&manifest.hlo_path(&key)?)?);
                }
            }
        } else {
            for &b in batches {
                let key = format!("fwd_b{b}");
                fwd.insert(b, self.compile_hlo_text(&manifest.hlo_path(&key)?)?);
            }
        }
        if fwd.is_empty() {
            bail!("{}: no fwd artifacts", manifest.name);
        }
        Ok(Arc::new(PjrtModel {
            manifest: manifest.clone(),
            fwd,
            qfwd,
        }))
    }

    fn cached(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }
}

/// A compiled computation bound to the backend's client.
#[derive(Clone)]
struct Executable {
    inner: Arc<ExecutableInner>,
    engine: Arc<Mutex<EngineInner>>,
}

impl Executable {
    /// Execute with literal inputs; unwraps the 1-tuple output (aot.py
    /// lowers with `return_tuple=True`) and returns the flat f32 vector.
    fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        // Serialize access through the engine mutex: the CPU client is a
        // single shared thread pool anyway (1-core testbed).
        let _guard = self.engine.lock().unwrap();
        let result = self.inner.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let lit = lit.to_tuple1()?;
        Ok(lit.to_vec::<f32>()?)
    }
}

/// Build a rank-N f32 literal from a flat slice.
fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {dims:?} wants {numel} elements, got {}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a rank-N u32 literal from a flat slice.
fn literal_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {dims:?} wants {numel} elements, got {}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// A model bound to compiled executables.
///
/// `fwd` variants take `(x, flat_weights)`; execution picks the largest
/// compiled batch ≤ n and loops/pads. The `qfwd` variant runs the L1
/// Pallas dequant kernel inside the executable.
struct PjrtModel {
    manifest: ModelManifest,
    fwd: BTreeMap<usize, Executable>,
    qfwd: BTreeMap<usize, Executable>,
}

impl PjrtModel {
    fn input_dims(&self, batch: usize) -> Vec<i64> {
        let mut dims = vec![batch as i64];
        dims.extend(self.manifest.input_shape.iter().map(|&d| d as i64));
        dims
    }

    /// Pick the executable batch for `n` samples: the largest compiled
    /// batch ≤ n, or the smallest one if n is below all of them.
    fn pick_batch(map: &BTreeMap<usize, Executable>, n: usize) -> usize {
        let mut best = None;
        for &b in map.keys() {
            if b <= n {
                best = Some(b);
            }
        }
        best.unwrap_or_else(|| *map.keys().next().unwrap())
    }
}

impl CompiledModel for PjrtModel {
    fn execute(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<Vec<f32>> {
        let ind = self.manifest.input_numel();
        let dim = self.manifest.output_dim();
        let mut out = Vec::with_capacity(n * dim);
        let mut done = 0;
        // weights literal is reusable across chunks of the same batch
        let mut wlit_cache: Option<xla::Literal> = None;
        let mut cached_batch = usize::MAX;
        while done < n {
            let batch = Self::pick_batch(&self.fwd, n - done);
            let exe = &self.fwd[&batch];
            let take = batch.min(n - done);
            let mut chunk = vec![0f32; batch * ind];
            chunk[..take * ind].copy_from_slice(&images[done * ind..(done + take) * ind]);
            let xlit = literal_f32(&chunk, &self.input_dims(batch))?;
            if cached_batch != batch || wlit_cache.is_none() {
                wlit_cache = Some(literal_f32(weights, &[weights.len() as i64])?);
                cached_batch = batch;
            }
            let res = exe.run_f32(&[xlit, wlit_cache.clone().unwrap()])?;
            anyhow::ensure!(res.len() == batch * dim, "unexpected output size");
            out.extend_from_slice(&res[..take * dim]);
            done += take;
        }
        Ok(out)
    }

    fn execute_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<Vec<f32>> {
        if self.qfwd.is_empty() {
            bail!("{}: no qfwd artifacts compiled", self.manifest.name);
        }
        let ind = self.manifest.input_numel();
        anyhow::ensure!(qflat.len() == self.manifest.param_count, "qflat size mismatch");
        let k = self.manifest.k;
        let scales: Vec<f32> = self
            .manifest
            .tensors
            .iter()
            .map(|t| {
                QuantParams {
                    min: t.min,
                    max: t.max,
                    k,
                }
                .dequant_scale()
            })
            .collect();
        let los: Vec<f32> = self.manifest.tensors.iter().map(|t| t.min).collect();
        let half = [half_correction(k, cum_bits)];
        let dim = self.manifest.output_dim();
        let mut out = Vec::with_capacity(n * dim);
        let mut done = 0;
        while done < n {
            let batch = Self::pick_batch(&self.qfwd, n - done);
            let exe = &self.qfwd[&batch];
            let take = batch.min(n - done);
            let mut chunk = vec![0f32; batch * ind];
            chunk[..take * ind].copy_from_slice(&images[done * ind..(done + take) * ind]);
            let res = exe.run_f32(&[
                literal_f32(&chunk, &self.input_dims(batch))?,
                literal_u32(qflat, &[qflat.len() as i64])?,
                literal_f32(&scales, &[scales.len() as i64])?,
                literal_f32(&los, &[los.len() as i64])?,
                literal_f32(&half, &[1])?,
            ])?;
            anyhow::ensure!(res.len() == batch * dim, "unexpected output size");
            out.extend_from_slice(&res[..take * dim]);
            done += take;
        }
        Ok(out)
    }

    fn supports_quantized(&self) -> bool {
        !self.qfwd.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_mismatch_rejected() {
        // the numel validation fires before any PJRT API is touched, so
        // this runs (and must keep passing) against the offline stub too
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_u32(&[1, 2, 3], &[2, 2]).is_err());
    }
}
