//! The pluggable execution-backend abstraction.
//!
//! The paper's pipeline needs an *executable runtime on every target
//! device*: approximate models are inferred **mid-download**, so whatever
//! executes the forward pass must accept a fresh flat weight vector at
//! every transmission stage. This module decouples that execution engine
//! from the rest of the system behind two small traits:
//!
//! - [`Backend`] — compiles a model description ([`ModelManifest`]) into an
//!   executable form, once per model.
//! - [`CompiledModel`] — executes the compiled forward pass, once per
//!   stage, against the weights reconstructed so far.
//!
//! Two implementations ship with the crate:
//!
//! - [`reference::ReferenceBackend`](super::reference::ReferenceBackend) —
//!   a dependency-free naive interpreter (matmul / conv / relu / softmax
//!   over the dequantized tensors). Always available; the default.
//! - `pjrt` (behind the `pjrt` cargo feature) — the XLA/PJRT CPU client
//!   executing the AOT-lowered HLO artifacts built by `python/compile/`.
//!
//! Weight *loading* is deliberately per-execution rather than per-compile:
//! progressive inference re-feeds the same compiled model with a new
//! reconstruction after every stage (§III-C of the paper), so weights are
//! an execute-time input, not a compile-time constant.

#![forbid(unsafe_code)]

use crate::util::sync::Arc;

use anyhow::{bail, Result};

use crate::models::ModelManifest;

/// An inference execution engine that can compile models and run them.
///
/// Implementations must be cheap to share (`Send + Sync`); the process
/// typically holds one backend instance behind an
/// [`Engine`](super::Engine) handle and compiles every served model
/// through it.
pub trait Backend: Send + Sync {
    /// Short stable identifier (`"reference"`, `"pjrt"`), used for CLI
    /// selection and diagnostics.
    fn name(&self) -> &'static str;

    /// Compile `manifest`'s forward pass.
    ///
    /// `batches` lists the batch sizes the caller intends to use; an empty
    /// slice means "every batch size the model's artifacts provide".
    /// Backends that are batch-size agnostic (the reference interpreter)
    /// may ignore the hint. Compilation results are cached inside the
    /// backend, keyed however the backend needs (artifact path, model
    /// name), so repeated calls are cheap.
    fn compile(
        &self,
        manifest: &ModelManifest,
        batches: &[usize],
    ) -> Result<Arc<dyn CompiledModel>>;

    /// Number of distinct compilation cache entries currently held.
    fn cached(&self) -> usize;
}

/// A model compiled by a [`Backend`], ready to execute.
///
/// All methods take the sample count `n` explicitly and return a flat
/// `n * output_dim` vector; shape validation against the manifest happens
/// in [`ModelSession`](super::ModelSession) before the call.
pub trait CompiledModel: Send + Sync {
    /// Run `n` samples through the float-weights forward path.
    ///
    /// `images` is `n * input_numel` floats, `weights` the flat f32
    /// parameter vector (any progressive reconstruction — this is called
    /// once per completed transmission stage with improving weights).
    fn execute(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<Vec<f32>>;

    /// Fused quantized forward path: raw `k`-bit codes in, Eq. 5
    /// dequantization inside the backend.
    ///
    /// `qflat` holds the bit-concatenated codes for all tensors,
    /// `cum_bits` the cumulative received bit-width (sets the midpoint
    /// correction for the not-yet-received low bits). Backends that have
    /// no fused path report it via [`CompiledModel::supports_quantized`].
    fn execute_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<Vec<f32>> {
        let _ = (images, n, qflat, cum_bits);
        bail!("this backend has no fused quantized execution path");
    }

    /// [`CompiledModel::execute_quantized`] with a caller-supplied
    /// monotone `version` identifying the exact contents of `qflat`
    /// (e.g. [`Assembler::codes_version`]): backends may cache the
    /// dequantized weight buffer under the `(cum_bits, version)` pair
    /// and skip Eq. 5 entirely when it repeats — the per-stage upgrade
    /// path of the reference interpreter does. The caller must bump
    /// `version` whenever `qflat` changes; a stale version yields stale
    /// weights. Default: ignore the hint.
    ///
    /// [`Assembler::codes_version`]: crate::client::Assembler::codes_version
    fn execute_quantized_versioned(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
        version: u64,
    ) -> Result<Vec<f32>> {
        let _ = version;
        self.execute_quantized(images, n, qflat, cum_bits)
    }

    /// Whether [`CompiledModel::execute_quantized`] is implemented.
    fn supports_quantized(&self) -> bool {
        false
    }

    /// Pipelined (layer-granular streaming) forward pass: block on
    /// `gate` per layer and execute each layer the moment its weights
    /// arrive, so inference overlaps the ongoing transfer instead of
    /// waiting for a full stage. `min_stage` is the lowest stage a layer
    /// must have absorbed before dispatch (0 = run on first arrival);
    /// when more stages have landed by dispatch time the newest is used.
    /// Returns the outputs plus the per-layer dispatch record
    /// ([`StreamStats`](super::stream::StreamStats)). Errors if the gate
    /// closes before every layer reached `min_stage`. Default:
    /// unsupported.
    fn execute_streaming(
        &self,
        images: &[f32],
        n: usize,
        gate: &super::stream::LayerGate,
        min_stage: usize,
    ) -> Result<(Vec<f32>, super::stream::StreamStats)> {
        let _ = (images, n, gate, min_stage);
        bail!("this backend has no streaming (layer-granular) execution path");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct NoQuant;

    impl CompiledModel for NoQuant {
        fn execute(&self, _images: &[f32], n: usize, _weights: &[f32]) -> Result<Vec<f32>> {
            Ok(vec![0.0; n])
        }
    }

    #[test]
    fn quantized_default_is_unsupported() {
        let m = NoQuant;
        assert!(!m.supports_quantized());
        assert!(m.execute_quantized(&[], 0, &[], 16).is_err());
        assert_eq!(m.execute(&[], 2, &[]).unwrap().len(), 2);
    }

    #[test]
    fn streaming_default_is_unsupported() {
        let gate = crate::runtime::stream::LayerGate::new(1);
        assert!(NoQuant.execute_streaming(&[], 0, &gate, 0).is_err());
    }
}
