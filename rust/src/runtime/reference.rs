//! The pure-Rust reference backend: a naive interpreter over the
//! dequantized tensors.
//!
//! The backend derives the layer graph from the manifest's tensor list —
//! the same convention `python/compile/model.py` uses to build every
//! architecture in the zoo:
//!
//! - a rank-4 weight `[3, 3, cin, cout]` followed by a rank-1 bias is a
//!   conv block (3×3 SAME convolution + bias + ReLU + 2×2 max-pool),
//! - a rank-2 weight `[cin, cout]` (optionally followed by its rank-1
//!   bias) is a dense layer — ReLU after every dense layer except the
//!   final head,
//! - for detection models the 4 box outputs after the class logits pass
//!   through a sigmoid, exactly like the JAX head.
//!
//! This executes anywhere `rustc` targets — no XLA, no artifacts — which
//! is what makes mid-download inference testable offline end to end. It
//! is a correctness baseline, not a speed demon; the feature-gated `pjrt`
//! backend exists for compiled execution.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::backend::{Backend, CompiledModel};
use super::ops;
use crate::models::{ModelManifest, TensorInfo};
use crate::quant::{dequantize_into, DequantParams};

/// A contiguous slice of the flat weight vector.
#[derive(Debug, Clone, Copy)]
struct Seg {
    offset: usize,
    len: usize,
}

impl Seg {
    fn of<'a>(&self, flat: &'a [f32]) -> &'a [f32] {
        &flat[self.offset..self.offset + self.len]
    }
}

/// One interpreted layer.
#[derive(Debug, Clone)]
enum Layer {
    /// 3×3 SAME conv + bias + ReLU + 2×2 max-pool on an NHWC activation.
    ConvBlock {
        w: Seg,
        b: Seg,
        h: usize,
        wd: usize,
        cin: usize,
        cout: usize,
    },
    /// `x @ w (+ b)`, ReLU unless this is the output head.
    Dense {
        w: Seg,
        b: Option<Seg>,
        cin: usize,
        cout: usize,
        relu: bool,
    },
}

/// Activation shape while walking the tensor list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Act {
    Spatial { h: usize, w: usize, c: usize },
    Flat(usize),
}

impl Act {
    fn numel(self) -> usize {
        match self {
            Act::Spatial { h, w, c } => h * w * c,
            Act::Flat(n) => n,
        }
    }
}

/// The compiled (planned) form of a model for the interpreter.
struct RefModel {
    layers: Vec<Layer>,
    input_numel: usize,
    output_dim: usize,
    /// sigmoid over columns `classes..output_dim` of the head (detection)
    sigmoid_from: Option<usize>,
    /// per-tensor metadata for the fused quantized path (Eq. 5 inside
    /// the backend)
    tensors: Vec<TensorInfo>,
    k: u32,
    param_count: usize,
}

/// Build the layer plan from a manifest, validating that tensor shapes
/// chain into a well-formed forward pass.
fn plan(manifest: &ModelManifest) -> Result<RefModel> {
    let mut act = match manifest.input_shape.len() {
        3 => Act::Spatial {
            h: manifest.input_shape[0],
            w: manifest.input_shape[1],
            c: manifest.input_shape[2],
        },
        _ => Act::Flat(manifest.input_shape.iter().product()),
    };
    let input_numel = act.numel();
    let mut layers = Vec::new();
    let ts = &manifest.tensors;
    let mut i = 0;
    while i < ts.len() {
        let t = &ts[i];
        let seg = |t: &TensorInfo| Seg {
            offset: t.offset,
            len: t.numel,
        };
        match t.shape.len() {
            4 => {
                if t.shape[0] != 3 || t.shape[1] != 3 {
                    bail!(
                        "{}: tensor '{}' has kernel {:?}; only 3x3 convs are supported",
                        manifest.name,
                        t.name,
                        &t.shape[..2]
                    );
                }
                let (cin, cout) = (t.shape[2], t.shape[3]);
                let Act::Spatial { h, w, c } = act else {
                    bail!(
                        "{}: conv tensor '{}' on a non-spatial activation",
                        manifest.name,
                        t.name
                    );
                };
                if c != cin {
                    bail!(
                        "{}: conv '{}' expects {cin} input channels, activation has {c}",
                        manifest.name,
                        t.name
                    );
                }
                let b = ts
                    .get(i + 1)
                    .filter(|b| b.shape.len() == 1 && b.numel == cout)
                    .with_context(|| {
                        format!("{}: conv '{}' is missing its bias", manifest.name, t.name)
                    })?;
                layers.push(Layer::ConvBlock {
                    w: seg(t),
                    b: seg(b),
                    h,
                    wd: w,
                    cin,
                    cout,
                });
                act = Act::Spatial {
                    h: h / 2,
                    w: w / 2,
                    c: cout,
                };
                i += 2;
            }
            2 => {
                let (cin, cout) = (t.shape[0], t.shape[1]);
                // a dense layer flattens a spatial activation (NHWC
                // row-major, matching `reshape(B, -1)` in the JAX models)
                if act.numel() != cin {
                    bail!(
                        "{}: dense '{}' expects {cin} inputs, activation has {}",
                        manifest.name,
                        t.name,
                        act.numel()
                    );
                }
                let b = ts
                    .get(i + 1)
                    .filter(|b| b.shape.len() == 1 && b.numel == cout)
                    .map(seg);
                i += if b.is_some() { 2 } else { 1 };
                layers.push(Layer::Dense {
                    w: seg(t),
                    b,
                    cin,
                    cout,
                    relu: true, // fixed up below for the head
                });
                act = Act::Flat(cout);
            }
            _ => bail!(
                "{}: tensor '{}' has unsupported rank {}",
                manifest.name,
                t.name,
                t.shape.len()
            ),
        }
    }
    let Some(Layer::Dense { relu, cout, .. }) = layers.last_mut() else {
        bail!("{}: model must end in a dense head", manifest.name);
    };
    *relu = false;
    let output_dim = *cout;
    if output_dim != manifest.output_dim() {
        bail!(
            "{}: head produces {output_dim} values, manifest says {}",
            manifest.name,
            manifest.output_dim()
        );
    }
    Ok(RefModel {
        layers,
        input_numel,
        output_dim,
        sigmoid_from: (manifest.task == "detect").then_some(manifest.classes),
        tensors: manifest.tensors.clone(),
        k: manifest.k,
        param_count: manifest.param_count,
    })
}

impl RefModel {
    /// Run one sample through the plan; returns `output_dim` floats.
    fn forward_one(&self, image: &[f32], weights: &[f32]) -> Vec<f32> {
        let mut act: Vec<f32> = image.to_vec();
        for layer in &self.layers {
            match layer {
                Layer::ConvBlock {
                    w,
                    b,
                    h,
                    wd,
                    cin,
                    cout,
                } => {
                    let mut conv = vec![0f32; h * wd * cout];
                    ops::conv3x3_same_bias_relu(
                        &act,
                        w.of(weights),
                        b.of(weights),
                        *h,
                        *wd,
                        *cin,
                        *cout,
                        &mut conv,
                    );
                    let (oh, ow) = (h / 2, wd / 2);
                    let mut pooled = vec![0f32; oh * ow * cout];
                    ops::maxpool2x2(&conv, *h, *wd, *cout, &mut pooled);
                    act = pooled;
                }
                Layer::Dense {
                    w,
                    b,
                    cin,
                    cout,
                    relu,
                } => {
                    let bias = b.map(|s| s.of(weights)).unwrap_or(&[]);
                    let mut out = vec![0f32; *cout];
                    ops::dense(&act, w.of(weights), bias, *cin, *cout, &mut out);
                    if *relu {
                        ops::relu(&mut out);
                    }
                    act = out;
                }
            }
        }
        if let Some(from) = self.sigmoid_from {
            for v in &mut act[from..] {
                *v = ops::sigmoid(*v);
            }
        }
        act
    }
}

impl CompiledModel for RefModel {
    fn execute(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n * self.output_dim);
        for i in 0..n {
            let image = &images[i * self.input_numel..(i + 1) * self.input_numel];
            out.extend_from_slice(&self.forward_one(image, weights));
        }
        Ok(out)
    }

    fn execute_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(qflat.len() == self.param_count, "qflat size mismatch");
        // Eq. 5 per tensor, then the plain float path — semantically the
        // same fusion the PJRT qfwd executable performs in-kernel.
        let mut weights = vec![0f32; self.param_count];
        for t in &self.tensors {
            let qp = crate::quant::QuantParams {
                min: t.min,
                max: t.max,
                k: self.k,
            };
            dequantize_into(
                &qflat[t.offset..t.offset + t.numel],
                DequantParams::new(&qp, cum_bits),
                &mut weights[t.offset..t.offset + t.numel],
            );
        }
        self.execute(images, n, &weights)
    }

    fn supports_quantized(&self) -> bool {
        true
    }
}

/// The dependency-free interpreter backend (the crate default).
///
/// Compilation is a shape-checked layer-plan derivation from the
/// manifest. Plans are cached by model name; each entry carries a
/// fingerprint of the manifest contents and is *replaced* on mismatch, so
/// a model re-published under the same name with different tensors (new
/// shapes or re-quantized min/max) never reuses a stale plan, and
/// superseded plans don't accumulate.
#[derive(Default)]
pub struct ReferenceBackend {
    cache: Mutex<HashMap<String, (u64, Arc<RefModel>)>>,
}

impl ReferenceBackend {
    /// Create an empty backend (no global state, cheap).
    pub fn new() -> Self {
        Self::default()
    }
}

/// Hash of everything the layer plan depends on.
fn fingerprint(manifest: &ModelManifest) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    manifest.task.hash(&mut h);
    manifest.classes.hash(&mut h);
    manifest.input_shape.hash(&mut h);
    manifest.param_count.hash(&mut h);
    manifest.k.hash(&mut h);
    for t in &manifest.tensors {
        t.name.hash(&mut h);
        t.shape.hash(&mut h);
        t.offset.hash(&mut h);
        t.min.to_bits().hash(&mut h);
        t.max.to_bits().hash(&mut h);
    }
    h.finish()
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn compile(
        &self,
        manifest: &ModelManifest,
        _batches: &[usize],
    ) -> Result<Arc<dyn CompiledModel>> {
        let fp = fingerprint(manifest);
        let mut cache = self.cache.lock().unwrap();
        if let Some((cached_fp, m)) = cache.get(&manifest.name) {
            if *cached_fp == fp {
                let shared: Arc<dyn CompiledModel> = m.clone();
                return Ok(shared);
            }
        }
        let model = Arc::new(plan(manifest)?);
        cache.insert(manifest.name.clone(), (fp, model.clone()));
        Ok(model)
    }

    fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;
    use crate::testutil::fixture;

    fn dense_registry(tag: &str) -> Registry {
        fixture::executable_models(tag).unwrap()
    }

    #[test]
    fn plan_builds_for_dense_chain() {
        let reg = dense_registry("ref-plan");
        let m = reg.get("dense3").unwrap();
        let backend = ReferenceBackend::new();
        let compiled = backend.compile(m, &[]).unwrap();
        assert!(compiled.supports_quantized());
        assert_eq!(backend.cached(), 1);
        // cache hit
        backend.compile(m, &[]).unwrap();
        assert_eq!(backend.cached(), 1);
    }

    #[test]
    fn republish_replaces_stale_plan() {
        let reg = dense_registry("ref-republish");
        let m = reg.get("dense3").unwrap();
        let backend = ReferenceBackend::new();
        backend.compile(m, &[]).unwrap();
        assert_eq!(backend.cached(), 1);
        // re-published under the same name with re-quantized weights:
        // the stale plan must be replaced, not reused and not leaked
        let mut m2 = m.clone();
        m2.tensors[0].min -= 0.5;
        backend.compile(&m2, &[]).unwrap();
        assert_eq!(backend.cached(), 1);
        // and dequant params in the new plan reflect the new manifest
        let fresh = backend.compile(&m2, &[]).unwrap();
        assert!(fresh.supports_quantized());
    }

    #[test]
    fn forward_matches_hand_computation() {
        // input 2 → dense(2,2) relu → dense(2,2) head, all weights known
        let dir = fixture::fixture_root("ref-hand");
        let _ = std::fs::remove_dir_all(&dir);
        let models = dir.join("models");
        std::fs::create_dir_all(&models).unwrap();
        // w1 = [[1, -1], [2, 0]], b1 = [0, 1], w2 = [[1, 0], [1, 1]], b2 = [0, 0]
        let flat = [1.0, -1.0, 2.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 0.0];
        fixture::write_model_with_weights(
            &models,
            "hand",
            &[
                ("fc1.w", &[2usize, 2][..]),
                ("fc1.b", &[2][..]),
                ("fc2.w", &[2, 2][..]),
                ("fc2.b", &[2][..]),
            ],
            &flat,
        )
        .unwrap();
        fixture::write_index(&models, &["hand"]).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let m = reg.get("hand").unwrap();
        let backend = ReferenceBackend::new();
        let compiled = backend.compile(m, &[]).unwrap();
        // x = [1, 2]: h = relu([1*1+2*2, 1*-1+2*0] + [0,1]) = relu([5, 0]) = [5, 0]
        // y = [5*1+0*1, 5*0+0*1] + [0,0] = [5, 0]
        let out = compiled.execute(&[1.0, 2.0], 1, &flat).unwrap();
        assert_eq!(out, vec![5.0, 0.0]);
    }

    #[test]
    fn quantized_path_converges_to_float_path() {
        use crate::quant::{quantize, QuantParams, K};
        let reg = dense_registry("ref-quant");
        let m = reg.get("dense3").unwrap();
        let flat = m.load_weights().unwrap();
        let backend = ReferenceBackend::new();
        let compiled = backend.compile(m, &[]).unwrap();
        let image: Vec<f32> = (0..m.input_numel()).map(|i| (i % 5) as f32 * 0.2).collect();
        let full = compiled.execute(&image, 1, &flat).unwrap();

        let mut qflat = vec![0u32; flat.len()];
        for t in &m.tensors {
            let seg = &flat[t.offset..t.offset + t.numel];
            let qp = QuantParams::from_data(seg, K);
            qflat[t.offset..t.offset + t.numel].copy_from_slice(&quantize(seg, &qp));
        }
        let q16 = compiled.execute_quantized(&image, 1, &qflat, K).unwrap();
        for (a, b) in full.iter().zip(&q16) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let dir = fixture::fixture_root("ref-bad");
        let _ = std::fs::remove_dir_all(&dir);
        let models = dir.join("models");
        std::fs::create_dir_all(&models).unwrap();
        // dense expects 4 inputs but input_shape will be [3] (first dim)
        fixture::write_model(&models, "bad", &[("w", &[3usize, 4][..]), ("w2", &[5, 2][..])], 7)
            .unwrap();
        fixture::write_index(&models, &["bad"]).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let m = reg.get("bad").unwrap();
        assert!(ReferenceBackend::new().compile(m, &[]).is_err());
    }
}
