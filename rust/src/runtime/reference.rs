//! The pure-Rust reference backend: a batched interpreter over the
//! dequantized tensors.
//!
//! The backend derives the layer graph from the manifest's tensor list —
//! the same convention `python/compile/model.py` uses to build every
//! architecture in the zoo:
//!
//! - a rank-4 weight `[3, 3, cin, cout]` followed by a rank-1 bias is a
//!   conv block (3×3 SAME convolution + bias + ReLU + 2×2 max-pool),
//! - a rank-2 weight `[cin, cout]` (optionally followed by its rank-1
//!   bias) is a dense layer — ReLU after every dense layer except the
//!   final head,
//! - for detection models the 4 box outputs after the class logits pass
//!   through a sigmoid, exactly like the JAX head.
//!
//! This executes anywhere `rustc` targets — no XLA, no artifacts — which
//! is what makes mid-download inference testable offline end to end.
//!
//! # Fast path
//!
//! Execution runs whole batches through the blocked kernels in
//! [`ops`]: dense layers are one register-tiled matmul over all samples,
//! conv blocks are im2col + the same matmul, and activations ping-pong
//! between two preallocated scratch buffers drawn from a
//! [`BufferPool`] — no per-sample or per-layer allocation. Batches of
//! `≥ 8` samples are sharded across a scoped worker pool of std threads
//! sized by [`super::threads`] (`PROGNET_THREADS` / `--threads`). The
//! fused quantized path keeps a per-plan dequantized-weight cache keyed
//! by `(cum_bits, codes_version)` so repeated calls against the same
//! stage skip Eq. 5 entirely.
//!
//! The pre-batched per-sample interpreter survives as the
//! `reference-scalar` backend ([`ReferenceBackend::scalar`]) — the
//! benchmark baseline and bit-exactness oracle for the batched kernels.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use crate::util::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::backend::{Backend, CompiledModel};
use super::ops;
use super::stream::{LayerDispatch, LayerGate, StreamStats};
use crate::models::{ModelManifest, TensorInfo};
use crate::quant::{dequantize_into, DequantParams, QuantParams};
use crate::util::pool::BufferPool;

/// A contiguous slice of the flat weight vector.
#[derive(Debug, Clone, Copy)]
struct Seg {
    offset: usize,
    len: usize,
}

impl Seg {
    fn of<'a>(&self, flat: &'a [f32]) -> &'a [f32] {
        &flat[self.offset..self.offset + self.len]
    }
}

/// One interpreted layer.
#[derive(Debug, Clone)]
enum Layer {
    /// 3×3 SAME conv + bias + ReLU + 2×2 max-pool on an NHWC activation.
    ConvBlock {
        w: Seg,
        b: Seg,
        h: usize,
        wd: usize,
        cin: usize,
        cout: usize,
    },
    /// `x @ w (+ b)`, ReLU unless this is the output head.
    Dense {
        w: Seg,
        b: Option<Seg>,
        cin: usize,
        cout: usize,
        relu: bool,
    },
}

/// Activation shape while walking the tensor list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Act {
    Spatial { h: usize, w: usize, c: usize },
    Flat(usize),
}

impl Act {
    fn numel(self) -> usize {
        match self {
            Act::Spatial { h, w, c } => h * w * c,
            Act::Flat(n) => n,
        }
    }
}

/// Dequantized-weight cache of the fused quantized path: one buffer per
/// plan, valid while the `(cum_bits, codes_version)` key repeats.
struct QCache {
    key: Option<(u32, u64)>,
    buf: Arc<Vec<f32>>,
}

/// The compiled (planned) form of a model for the interpreter.
struct RefModel {
    layers: Vec<Layer>,
    input_numel: usize,
    output_dim: usize,
    /// sigmoid over columns `classes..output_dim` of the head (detection)
    sigmoid_from: Option<usize>,
    /// per-tensor metadata for the fused quantized path (Eq. 5 inside
    /// the backend)
    tensors: Vec<TensorInfo>,
    k: u32,
    param_count: usize,
    /// per-sample capacity each ping-pong activation buffer needs (max
    /// over the input, every conv output and every layer output)
    buf_numel: usize,
    /// per-sample im2col scratch capacity (largest conv layer; 0 for
    /// pure-dense models)
    col_numel: usize,
    /// worker threads for batch sharding (resolved at compile time)
    threads: usize,
    /// run the pre-batched per-sample oracle path instead
    scalar: bool,
    scratch: BufferPool<f32>,
    qcache: Mutex<QCache>,
}

/// Build the layer plan from a manifest, validating that tensor shapes
/// chain into a well-formed forward pass.
fn plan(manifest: &ModelManifest, threads: usize, scalar: bool) -> Result<RefModel> {
    let mut act = match manifest.input_shape.len() {
        3 => Act::Spatial {
            h: manifest.input_shape[0],
            w: manifest.input_shape[1],
            c: manifest.input_shape[2],
        },
        _ => Act::Flat(manifest.input_shape.iter().product()),
    };
    let input_numel = act.numel();
    let mut layers = Vec::new();
    let ts = &manifest.tensors;
    let mut i = 0;
    while i < ts.len() {
        let t = &ts[i];
        let seg = |t: &TensorInfo| Seg {
            offset: t.offset,
            len: t.numel,
        };
        match t.shape.len() {
            4 => {
                if t.shape[0] != 3 || t.shape[1] != 3 {
                    bail!(
                        "{}: tensor '{}' has kernel {:?}; only 3x3 convs are supported",
                        manifest.name,
                        t.name,
                        &t.shape[..2]
                    );
                }
                let (cin, cout) = (t.shape[2], t.shape[3]);
                let Act::Spatial { h, w, c } = act else {
                    bail!(
                        "{}: conv tensor '{}' on a non-spatial activation",
                        manifest.name,
                        t.name
                    );
                };
                if c != cin {
                    bail!(
                        "{}: conv '{}' expects {cin} input channels, activation has {c}",
                        manifest.name,
                        t.name
                    );
                }
                let b = ts
                    .get(i + 1)
                    .filter(|b| b.shape.len() == 1 && b.numel == cout)
                    .with_context(|| {
                        format!("{}: conv '{}' is missing its bias", manifest.name, t.name)
                    })?;
                layers.push(Layer::ConvBlock {
                    w: seg(t),
                    b: seg(b),
                    h,
                    wd: w,
                    cin,
                    cout,
                });
                act = Act::Spatial {
                    h: h / 2,
                    w: w / 2,
                    c: cout,
                };
                i += 2;
            }
            2 => {
                let (cin, cout) = (t.shape[0], t.shape[1]);
                // a dense layer flattens a spatial activation (NHWC
                // row-major, matching `reshape(B, -1)` in the JAX models)
                if act.numel() != cin {
                    bail!(
                        "{}: dense '{}' expects {cin} inputs, activation has {}",
                        manifest.name,
                        t.name,
                        act.numel()
                    );
                }
                let b = ts
                    .get(i + 1)
                    .filter(|b| b.shape.len() == 1 && b.numel == cout)
                    .map(seg);
                i += if b.is_some() { 2 } else { 1 };
                layers.push(Layer::Dense {
                    w: seg(t),
                    b,
                    cin,
                    cout,
                    relu: true, // fixed up below for the head
                });
                act = Act::Flat(cout);
            }
            _ => bail!(
                "{}: tensor '{}' has unsupported rank {}",
                manifest.name,
                t.name,
                t.shape.len()
            ),
        }
    }
    let Some(Layer::Dense { relu, cout, .. }) = layers.last_mut() else {
        bail!("{}: model must end in a dense head", manifest.name);
    };
    *relu = false;
    let output_dim = *cout;
    if output_dim != manifest.output_dim() {
        bail!(
            "{}: head produces {output_dim} values, manifest says {}",
            manifest.name,
            manifest.output_dim()
        );
    }
    // scratch sizing: both ping-pong buffers must hold any activation AND
    // any pre-pool conv output; the im2col panel must hold the largest
    // conv layer's patch rows
    let mut buf_numel = input_numel;
    let mut col_numel = 0usize;
    for layer in &layers {
        match *layer {
            Layer::ConvBlock {
                h,
                wd,
                cin,
                cout,
                ..
            } => {
                buf_numel = buf_numel.max(h * wd * cout);
                col_numel = col_numel.max(h * wd * 9 * cin);
            }
            Layer::Dense { cout, .. } => buf_numel = buf_numel.max(cout),
        }
    }
    Ok(RefModel {
        layers,
        input_numel,
        output_dim,
        sigmoid_from: (manifest.task == "detect").then_some(manifest.classes),
        tensors: manifest.tensors.clone(),
        k: manifest.k,
        param_count: manifest.param_count,
        buf_numel,
        col_numel,
        threads: threads.max(1),
        scalar,
        scratch: BufferPool::default(),
        qcache: Mutex::new(QCache {
            key: None,
            buf: Arc::new(Vec::new()),
        }),
    })
}

impl RefModel {
    /// Run one sample through the plan; returns `output_dim` floats.
    fn forward_one(&self, image: &[f32], weights: &[f32]) -> Vec<f32> {
        let mut act: Vec<f32> = image.to_vec();
        for layer in &self.layers {
            match layer {
                Layer::ConvBlock {
                    w,
                    b,
                    h,
                    wd,
                    cin,
                    cout,
                } => {
                    let mut conv = vec![0f32; h * wd * cout];
                    ops::conv3x3_same_bias_relu(
                        &act,
                        w.of(weights),
                        b.of(weights),
                        *h,
                        *wd,
                        *cin,
                        *cout,
                        &mut conv,
                    );
                    let (oh, ow) = (h / 2, wd / 2);
                    let mut pooled = vec![0f32; oh * ow * cout];
                    ops::maxpool2x2(&conv, *h, *wd, *cout, &mut pooled);
                    act = pooled;
                }
                Layer::Dense {
                    w,
                    b,
                    cin,
                    cout,
                    relu,
                } => {
                    let bias = b.map(|s| s.of(weights)).unwrap_or(&[]);
                    let mut out = vec![0f32; *cout];
                    ops::dense(&act, w.of(weights), bias, *cin, *cout, &mut out);
                    if *relu {
                        ops::relu(&mut out);
                    }
                    act = out;
                }
            }
        }
        if let Some(from) = self.sigmoid_from {
            for v in &mut act[from..] {
                *v = ops::sigmoid(*v);
            }
        }
        act
    }

    /// Run `n` samples as one batch through the blocked kernels, writing
    /// `n * output_dim` floats into `out`. Activations live in two
    /// pooled ping-pong buffers; the invariant is "current activation in
    /// `ping`" (conv blocks pool back into `ping`, dense layers swap).
    fn forward_batch(&self, images: &[f32], n: usize, weights: &[f32], out: &mut [f32]) {
        debug_assert_eq!(images.len(), n * self.input_numel);
        debug_assert_eq!(out.len(), n * self.output_dim);
        let mut ping = self.scratch.take(n * self.buf_numel);
        let mut pong = self.scratch.take(n * self.buf_numel);
        let mut col = self.scratch.take(n * self.col_numel);
        ping[..images.len()].copy_from_slice(images);
        let mut cur_numel = self.input_numel;
        for layer in &self.layers {
            cur_numel =
                self.layer_step(layer, n, cur_numel, weights, &mut ping, &mut pong, &mut col);
        }
        out.copy_from_slice(&ping[..n * self.output_dim]);
        if let Some(from) = self.sigmoid_from {
            for row in out.chunks_exact_mut(self.output_dim) {
                for v in &mut row[from..] {
                    *v = ops::sigmoid(*v);
                }
            }
        }
        self.scratch.put(ping);
        self.scratch.put(pong);
        self.scratch.put(col);
    }

    /// One planned layer over the whole batch, upholding the ping-pong
    /// invariant ("current activation in `ping`"). Shared by the batch
    /// and streaming paths. Returns the new per-sample activation numel.
    #[allow(clippy::too_many_arguments)]
    fn layer_step(
        &self,
        layer: &Layer,
        n: usize,
        cur_numel: usize,
        weights: &[f32],
        ping: &mut Vec<f32>,
        pong: &mut Vec<f32>,
        col: &mut Vec<f32>,
    ) -> usize {
        // lint:hot-path — runs entirely in pooled scratch; all
        // allocation happened in the callers' `scratch.take` calls
        match *layer {
            Layer::ConvBlock {
                w,
                b,
                h,
                wd,
                cin,
                cout,
            } => {
                let patch = 9 * cin;
                let pixels = h * wd;
                // whole-batch im2col, then ONE matmul over n·h·w rows
                for s in 0..n {
                    ops::im2col3x3(
                        &ping[s * cur_numel..][..cur_numel],
                        h,
                        wd,
                        cin,
                        &mut col[s * pixels * patch..][..pixels * patch],
                    );
                }
                ops::matmul_bias_relu(
                    &col[..n * pixels * patch],
                    w.of(weights),
                    b.of(weights),
                    n * pixels,
                    patch,
                    cout,
                    true,
                    &mut pong[..n * pixels * cout],
                );
                // pool back into ping: sample s writes below its own
                // (already-consumed) input region, so no aliasing
                let pooled = (h / 2) * (wd / 2) * cout;
                for s in 0..n {
                    ops::maxpool2x2(
                        &pong[s * pixels * cout..][..pixels * cout],
                        h,
                        wd,
                        cout,
                        &mut ping[s * pooled..][..pooled],
                    );
                }
                pooled
            }
            Layer::Dense {
                w,
                b,
                cin,
                cout,
                relu,
            } => {
                debug_assert_eq!(cin, cur_numel);
                let bias = b.map(|s| s.of(weights)).unwrap_or(&[]);
                ops::matmul_bias_relu(
                    &ping[..n * cin],
                    w.of(weights),
                    bias,
                    n,
                    cin,
                    cout,
                    relu,
                    &mut pong[..n * cout],
                );
                std::mem::swap(ping, pong);
                cout
            }
        }
        // lint:end-hot-path
    }

    /// Pipelined forward pass: block on `gate` per layer and run each
    /// layer the moment its weights arrive ([`LayerGate::wait`]), so
    /// inference begins once layer 0 lands while later layers are still
    /// in flight. Weights accumulate segment by segment in a pooled
    /// buffer; each layer reads only its own (already-copied) segment.
    /// The plan's layer list and the gate's layer annotation derive from
    /// the same rank convention ([`crate::format::header::infer_layer_groups`]),
    /// which the count check below enforces.
    fn forward_streaming(
        &self,
        images: &[f32],
        n: usize,
        gate: &LayerGate,
        min_stage: usize,
        out: &mut [f32],
    ) -> Result<StreamStats> {
        anyhow::ensure!(
            gate.layers() == self.layers.len(),
            "gate announces {} layers, plan has {}",
            gate.layers(),
            self.layers.len()
        );
        debug_assert_eq!(images.len(), n * self.input_numel);
        debug_assert_eq!(out.len(), n * self.output_dim);
        let mut weights = self.scratch.take(self.param_count);
        let mut ping = self.scratch.take(n * self.buf_numel);
        let mut pong = self.scratch.take(n * self.buf_numel);
        let mut col = self.scratch.take(n * self.col_numel);
        ping[..images.len()].copy_from_slice(images);
        let mut cur_numel = self.input_numel;
        let mut stats = StreamStats::default();
        for (li, layer) in self.layers.iter().enumerate() {
            let up = gate.wait(li, min_stage).with_context(|| {
                format!("gate closed before layer {li} reached stage {min_stage}")
            })?;
            weights[up.range.clone()].copy_from_slice(&up.seg);
            stats.dispatches.push(LayerDispatch {
                layer: li,
                stage: up.stage,
                t: up.t,
            });
            cur_numel =
                self.layer_step(layer, n, cur_numel, &weights, &mut ping, &mut pong, &mut col);
        }
        debug_assert_eq!(cur_numel, self.output_dim);
        out.copy_from_slice(&ping[..n * self.output_dim]);
        if let Some(from) = self.sigmoid_from {
            for row in out.chunks_exact_mut(self.output_dim) {
                for v in &mut row[from..] {
                    *v = ops::sigmoid(*v);
                }
            }
        }
        self.scratch.put(weights);
        self.scratch.put(ping);
        self.scratch.put(pong);
        self.scratch.put(col);
        Ok(stats)
    }

    /// Contiguous shards for a batch of `n`: 1 below the sharding
    /// threshold, else capped so every worker gets ≥ 4 samples.
    fn shard_count(&self, n: usize) -> usize {
        if self.threads <= 1 || n < 8 {
            1
        } else {
            // n ≥ 8 ⇒ n/4 ≥ 2, so this never degenerates to 0 shards
            self.threads.min(n / 4)
        }
    }

    /// Eq. 5 over all tensors into the plan's cached weight buffer.
    ///
    /// With a `(cum_bits, version)` key that matches the cache, the
    /// buffer is reused as-is (zero dequant work). On a miss the dequant
    /// runs *outside* the cache lock — concurrent callers proceed in
    /// parallel, exactly like the old per-call allocation path — and the
    /// retired allocation is recycled whenever no reader still holds it.
    /// Unversioned calls never evict a live versioned entry.
    fn dequant_weights(
        &self,
        qflat: &[u32],
        cum_bits: u32,
        key: Option<(u32, u64)>,
    ) -> Arc<Vec<f32>> {
        // steal the cached allocation only when this call will store its
        // result back; an unversioned call racing a versioned entry must
        // leave the entry (key AND buffer) untouched
        let store;
        let mut buf = {
            let mut cache = self.qcache.lock().unwrap();
            if key.is_some() && cache.key == key && cache.buf.len() == self.param_count {
                return cache.buf.clone();
            }
            store = key.is_some() || cache.key.is_none();
            if store {
                cache.key = None; // entry is being rebuilt
                let old = std::mem::replace(&mut cache.buf, Arc::new(Vec::new()));
                Arc::try_unwrap(old).unwrap_or_default()
            } else {
                Vec::new()
            }
        };
        buf.resize(self.param_count, 0.0);
        for t in &self.tensors {
            let qp = QuantParams {
                min: t.min,
                max: t.max,
                k: self.k,
            };
            dequantize_into(
                &qflat[t.offset..t.offset + t.numel],
                DequantParams::new(&qp, cum_bits),
                &mut buf[t.offset..t.offset + t.numel],
            );
        }
        let arc = Arc::new(buf);
        if store {
            let mut cache = self.qcache.lock().unwrap();
            // re-check under the lock: an unversioned result must not
            // clobber a versioned entry stored by a concurrent caller
            // between our two critical sections
            if key.is_some() || cache.key.is_none() {
                cache.buf = arc.clone();
                cache.key = key;
            }
        }
        arc
    }
}

impl CompiledModel for RefModel {
    fn execute(&self, images: &[f32], n: usize, weights: &[f32]) -> Result<Vec<f32>> {
        if self.scalar {
            // the pre-batched oracle: one sample at a time, per-layer Vecs
            let mut out = Vec::with_capacity(n * self.output_dim);
            for i in 0..n {
                let image = &images[i * self.input_numel..(i + 1) * self.input_numel];
                out.extend_from_slice(&self.forward_one(image, weights));
            }
            return Ok(out);
        }
        let mut out = vec![0f32; n * self.output_dim];
        let shards = self.shard_count(n);
        if shards <= 1 {
            self.forward_batch(images, n, weights, &mut out);
        } else {
            let per = (n + shards - 1) / shards;
            std::thread::scope(|scope| {
                let mut rest = &mut out[..];
                let mut off = 0;
                while off < n {
                    let m = per.min(n - off);
                    let (o, tail) = rest.split_at_mut(m * self.output_dim);
                    rest = tail;
                    let img = &images[off * self.input_numel..(off + m) * self.input_numel];
                    scope.spawn(move || self.forward_batch(img, m, weights, o));
                    off += m;
                }
            });
        }
        Ok(out)
    }

    fn execute_quantized(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(qflat.len() == self.param_count, "qflat size mismatch");
        // Eq. 5 per tensor, then the plain float path — semantically the
        // same fusion the PJRT qfwd executable performs in-kernel. The
        // buffer allocation is recycled, but without a version key the
        // dequant itself always re-runs.
        let weights = self.dequant_weights(qflat, cum_bits, None);
        self.execute(images, n, &weights)
    }

    fn execute_quantized_versioned(
        &self,
        images: &[f32],
        n: usize,
        qflat: &[u32],
        cum_bits: u32,
        version: u64,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(qflat.len() == self.param_count, "qflat size mismatch");
        let weights = self.dequant_weights(qflat, cum_bits, Some((cum_bits, version)));
        self.execute(images, n, &weights)
    }

    fn supports_quantized(&self) -> bool {
        true
    }

    fn execute_streaming(
        &self,
        images: &[f32],
        n: usize,
        gate: &LayerGate,
        min_stage: usize,
    ) -> Result<(Vec<f32>, StreamStats)> {
        anyhow::ensure!(
            images.len() == n * self.input_numel,
            "streaming batch is {} floats, expected {}",
            images.len(),
            n * self.input_numel
        );
        let mut out = vec![0f32; n * self.output_dim];
        let stats = self.forward_streaming(images, n, gate, min_stage, &mut out)?;
        Ok((out, stats))
    }
}

/// The dependency-free interpreter backend (the crate default).
///
/// Compilation is a shape-checked layer-plan derivation from the
/// manifest. Plans are cached by model name; each entry carries a
/// fingerprint of the manifest contents and is *replaced* on mismatch, so
/// a model re-published under the same name with different tensors (new
/// shapes or re-quantized min/max) never reuses a stale plan, and
/// superseded plans don't accumulate.
///
/// [`ReferenceBackend::new`] builds the batched fast path with the
/// process-wide worker count ([`super::threads`]);
/// [`ReferenceBackend::with_threads`] pins an explicit count (tests,
/// benches); [`ReferenceBackend::scalar`] builds the per-sample oracle
/// interpreter (`--backend reference-scalar`).
pub struct ReferenceBackend {
    cache: Mutex<HashMap<String, (u64, Arc<RefModel>)>>,
    threads: usize,
    scalar: bool,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    /// The batched fast path, worker count snapshotted from
    /// [`super::threads`] (no other global state, cheap).
    pub fn new() -> Self {
        Self::with_threads(super::threads())
    }

    /// The batched fast path with an explicit worker count (`0` = 1).
    pub fn with_threads(threads: usize) -> Self {
        Self {
            cache: Mutex::new(HashMap::new()),
            threads: threads.max(1),
            scalar: false,
        }
    }

    /// The pre-batched per-sample interpreter — the benchmark baseline
    /// and bit-exactness oracle for the batched kernels.
    pub fn scalar() -> Self {
        Self {
            cache: Mutex::new(HashMap::new()),
            threads: 1,
            scalar: true,
        }
    }
}

/// Hash of everything the layer plan depends on.
fn fingerprint(manifest: &ModelManifest) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    manifest.task.hash(&mut h);
    manifest.classes.hash(&mut h);
    manifest.input_shape.hash(&mut h);
    manifest.param_count.hash(&mut h);
    manifest.k.hash(&mut h);
    for t in &manifest.tensors {
        t.name.hash(&mut h);
        t.shape.hash(&mut h);
        t.offset.hash(&mut h);
        t.min.to_bits().hash(&mut h);
        t.max.to_bits().hash(&mut h);
    }
    h.finish()
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        if self.scalar {
            "reference-scalar"
        } else {
            "reference"
        }
    }

    fn compile(
        &self,
        manifest: &ModelManifest,
        _batches: &[usize],
    ) -> Result<Arc<dyn CompiledModel>> {
        let fp = fingerprint(manifest);
        let mut cache = self.cache.lock().unwrap();
        if let Some((cached_fp, m)) = cache.get(&manifest.name) {
            if *cached_fp == fp {
                let shared: Arc<dyn CompiledModel> = m.clone();
                return Ok(shared);
            }
        }
        let model = Arc::new(plan(manifest, self.threads, self.scalar)?);
        cache.insert(manifest.name.clone(), (fp, model.clone()));
        Ok(model)
    }

    fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;
    use crate::testutil::fixture;

    fn dense_registry(tag: &str) -> Registry {
        fixture::executable_models(tag).unwrap()
    }

    #[test]
    fn plan_builds_for_dense_chain() {
        let reg = dense_registry("ref-plan");
        let m = reg.get("dense3").unwrap();
        let backend = ReferenceBackend::new();
        let compiled = backend.compile(m, &[]).unwrap();
        assert!(compiled.supports_quantized());
        assert_eq!(backend.cached(), 1);
        // cache hit
        backend.compile(m, &[]).unwrap();
        assert_eq!(backend.cached(), 1);
    }

    #[test]
    fn republish_replaces_stale_plan() {
        let reg = dense_registry("ref-republish");
        let m = reg.get("dense3").unwrap();
        let backend = ReferenceBackend::new();
        backend.compile(m, &[]).unwrap();
        assert_eq!(backend.cached(), 1);
        // re-published under the same name with re-quantized weights:
        // the stale plan must be replaced, not reused and not leaked
        let mut m2 = m.clone();
        m2.tensors[0].min -= 0.5;
        backend.compile(&m2, &[]).unwrap();
        assert_eq!(backend.cached(), 1);
        // and dequant params in the new plan reflect the new manifest
        let fresh = backend.compile(&m2, &[]).unwrap();
        assert!(fresh.supports_quantized());
    }

    #[test]
    fn forward_matches_hand_computation() {
        // input 2 → dense(2,2) relu → dense(2,2) head, all weights known
        let dir = fixture::fixture_root("ref-hand");
        let _ = std::fs::remove_dir_all(&dir);
        let models = dir.join("models");
        std::fs::create_dir_all(&models).unwrap();
        // w1 = [[1, -1], [2, 0]], b1 = [0, 1], w2 = [[1, 0], [1, 1]], b2 = [0, 0]
        let flat = [1.0, -1.0, 2.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 0.0];
        fixture::write_model_with_weights(
            &models,
            "hand",
            &[
                ("fc1.w", &[2usize, 2][..]),
                ("fc1.b", &[2][..]),
                ("fc2.w", &[2, 2][..]),
                ("fc2.b", &[2][..]),
            ],
            &flat,
        )
        .unwrap();
        fixture::write_index(&models, &["hand"]).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let m = reg.get("hand").unwrap();
        let backend = ReferenceBackend::new();
        let compiled = backend.compile(m, &[]).unwrap();
        // x = [1, 2]: h = relu([1*1+2*2, 1*-1+2*0] + [0,1]) = relu([5, 0]) = [5, 0]
        // y = [5*1+0*1, 5*0+0*1] + [0,0] = [5, 0]
        let out = compiled.execute(&[1.0, 2.0], 1, &flat).unwrap();
        assert_eq!(out, vec![5.0, 0.0]);
    }

    #[test]
    fn quantized_path_converges_to_float_path() {
        use crate::quant::{quantize, QuantParams, K};
        let reg = dense_registry("ref-quant");
        let m = reg.get("dense3").unwrap();
        let flat = m.load_weights().unwrap();
        let backend = ReferenceBackend::new();
        let compiled = backend.compile(m, &[]).unwrap();
        let image: Vec<f32> = (0..m.input_numel()).map(|i| (i % 5) as f32 * 0.2).collect();
        let full = compiled.execute(&image, 1, &flat).unwrap();

        let mut qflat = vec![0u32; flat.len()];
        for t in &m.tensors {
            let seg = &flat[t.offset..t.offset + t.numel];
            let qp = QuantParams::from_data(seg, K);
            qflat[t.offset..t.offset + t.numel].copy_from_slice(&quantize(seg, &qp));
        }
        let q16 = compiled.execute_quantized(&image, 1, &qflat, K).unwrap();
        for (a, b) in full.iter().zip(&q16) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_path_matches_scalar_oracle() {
        let reg = dense_registry("ref-batched");
        let m = reg.get("dense3").unwrap();
        let flat = m.load_weights().unwrap();
        let fast = ReferenceBackend::with_threads(2).compile(m, &[]).unwrap();
        let slow = ReferenceBackend::scalar().compile(m, &[]).unwrap();
        for n in [1usize, 3, 4, 7, 8, 33] {
            let images: Vec<f32> = (0..n * m.input_numel())
                .map(|i| (i % 11) as f32 * 0.1 - 0.5)
                .collect();
            let a = fast.execute(&images, n, &flat).unwrap();
            let b = slow.execute(&images, n, &flat).unwrap();
            assert_eq!(a, b, "batch {n}");
        }
    }

    #[test]
    fn scalar_backend_is_selectable_and_named() {
        let backend = ReferenceBackend::scalar();
        assert_eq!(backend.name(), "reference-scalar");
        assert_eq!(ReferenceBackend::with_threads(4).name(), "reference");
    }

    #[test]
    fn quantized_versioned_reuses_cached_weights() {
        use crate::quant::{quantize, QuantParams, K};
        let reg = dense_registry("ref-qcache");
        let m = reg.get("dense3").unwrap();
        let flat = m.load_weights().unwrap();
        let compiled = ReferenceBackend::with_threads(1).compile(m, &[]).unwrap();
        let mut qflat = vec![0u32; flat.len()];
        for t in &m.tensors {
            let seg = &flat[t.offset..t.offset + t.numel];
            let qp = QuantParams::from_data(seg, K);
            qflat[t.offset..t.offset + t.numel].copy_from_slice(&quantize(seg, &qp));
        }
        let image: Vec<f32> = (0..m.input_numel()).map(|i| i as f32 * 0.1).collect();
        let plain = compiled.execute_quantized(&image, 1, &qflat, K).unwrap();
        // same (cum_bits, version) twice: second call serves from cache
        let v1 = compiled
            .execute_quantized_versioned(&image, 1, &qflat, K, 7)
            .unwrap();
        let v2 = compiled
            .execute_quantized_versioned(&image, 1, &qflat, K, 7)
            .unwrap();
        assert_eq!(plain, v1);
        assert_eq!(v1, v2);
        // a new version with mutated codes must invalidate the cache
        let mut qflat2 = qflat.clone();
        for v in qflat2.iter_mut() {
            *v = (*v).wrapping_add(1) & 0xFFFF;
        }
        let v3 = compiled
            .execute_quantized_versioned(&image, 1, &qflat2, K, 8)
            .unwrap();
        let direct = compiled.execute_quantized(&image, 1, &qflat2, K).unwrap();
        assert_eq!(v3, direct);
        assert_ne!(v1, v3);
    }

    /// (layer, flat range) pairs per the manifest's rank convention —
    /// the same grouping `plan` and `infer_layer_groups` derive.
    fn layer_ranges(m: &ModelManifest) -> Vec<std::ops::Range<usize>> {
        let shapes: Vec<&[usize]> = m.tensors.iter().map(|t| t.shape.as_slice()).collect();
        let groups = crate::format::header::infer_layer_groups(&shapes);
        let mut out = Vec::new();
        let mut ti = 0;
        for &c in &groups {
            let first = &m.tensors[ti];
            let last = &m.tensors[ti + c - 1];
            out.push(first.offset..last.offset + last.numel);
            ti += c;
        }
        out
    }

    #[test]
    fn streaming_matches_batch_when_all_layers_published() {
        use crate::runtime::stream::LayerGate;
        let reg = dense_registry("ref-stream");
        let m = reg.get("dense3").unwrap();
        let flat = m.load_weights().unwrap();
        let compiled = ReferenceBackend::with_threads(1).compile(m, &[]).unwrap();
        let ranges = layer_ranges(m);
        let gate = LayerGate::new(ranges.len());
        for (l, r) in ranges.iter().enumerate() {
            gate.publish_layer(l, 0, l as f64 * 0.5, r.clone(), &flat[r.clone()]);
        }
        let n = 3;
        let images: Vec<f32> = (0..n * m.input_numel())
            .map(|i| (i % 7) as f32 * 0.1)
            .collect();
        let (got, stats) = compiled.execute_streaming(&images, n, &gate, 0).unwrap();
        let want = compiled.execute(&images, n, &flat).unwrap();
        assert_eq!(got, want);
        // dispatch record carries the publish timestamps, in layer order
        assert_eq!(stats.dispatches.len(), ranges.len());
        assert_eq!(stats.t_first_dispatch(), 0.0);
        assert_eq!(stats.t_last_dispatch(), (ranges.len() - 1) as f64 * 0.5);
        for (l, d) in stats.dispatches.iter().enumerate() {
            assert_eq!((d.layer, d.stage), (l, 0));
        }
    }

    #[test]
    fn streaming_blocks_until_each_layer_arrives() {
        use crate::runtime::stream::LayerGate;
        let reg = dense_registry("ref-stream-late");
        let m = reg.get("dense3").unwrap();
        let flat = m.load_weights().unwrap();
        let compiled = ReferenceBackend::with_threads(1).compile(m, &[]).unwrap();
        let ranges = layer_ranges(m);
        let gate = Arc::new(LayerGate::new(ranges.len()));
        let images: Vec<f32> = (0..m.input_numel()).map(|i| (i % 5) as f32 * 0.2).collect();
        let publisher = {
            let gate = gate.clone();
            let flat = flat.clone();
            let ranges = ranges.clone();
            std::thread::spawn(move || {
                for (l, r) in ranges.iter().enumerate() {
                    gate.publish_layer(l, 0, l as f64, r.clone(), &flat[r.clone()]);
                    std::thread::yield_now();
                }
            })
        };
        let (got, _) = compiled.execute_streaming(&images, 1, &gate, 0).unwrap();
        publisher.join().unwrap();
        assert_eq!(got, compiled.execute(&images, 1, &flat).unwrap());
    }

    #[test]
    fn streaming_errors_on_closed_gate_and_bad_sizing() {
        use crate::runtime::stream::LayerGate;
        let reg = dense_registry("ref-stream-err");
        let m = reg.get("dense3").unwrap();
        let compiled = ReferenceBackend::with_threads(1).compile(m, &[]).unwrap();
        let images: Vec<f32> = vec![0.0; m.input_numel()];
        // a gate sized for a different plan is a config error
        let wrong = LayerGate::new(layer_ranges(m).len() + 1);
        assert!(compiled.execute_streaming(&images, 1, &wrong, 0).is_err());
        // a closed, undelivered gate errors out instead of hanging
        let closed = LayerGate::new(layer_ranges(m).len());
        closed.close();
        assert!(compiled.execute_streaming(&images, 1, &closed, 0).is_err());
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let dir = fixture::fixture_root("ref-bad");
        let _ = std::fs::remove_dir_all(&dir);
        let models = dir.join("models");
        std::fs::create_dir_all(&models).unwrap();
        // dense expects 4 inputs but input_shape will be [3] (first dim)
        fixture::write_model(&models, "bad", &[("w", &[3usize, 4][..]), ("w2", &[5, 2][..])], 7)
            .unwrap();
        fixture::write_index(&models, &["bad"]).unwrap();
        let reg = Registry::open(&dir).unwrap();
        let m = reg.get("bad").unwrap();
        assert!(ReferenceBackend::new().compile(m, &[]).is_err());
    }
}
