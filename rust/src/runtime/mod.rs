//! PJRT runtime — loads the AOT HLO-text artifacts and executes them on
//! the request path (python is never involved at runtime).
//!
//! - [`engine::Engine`] — process-wide PJRT CPU client + executable cache.
//! - [`session::ModelSession`] — per-model staged execution: feeds images
//!   plus a flat weight vector (or quantized planes for the fused-dequant
//!   `qfwd` variant) into the compiled executable at the best batch size.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits serialized protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod session;

pub use engine::{Engine, Executable};
pub use session::{InferOutput, ModelSession};
