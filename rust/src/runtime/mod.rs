//! Pluggable inference runtime — executes progressive reconstructions on
//! the request path (python is never involved at runtime).
//!
//! The runtime is split into a small trait layer and interchangeable
//! backends:
//!
//! - [`Backend`] / [`CompiledModel`] — the compile / load-weights /
//!   execute contract every execution engine implements.
//! - [`ReferenceBackend`] — pure-Rust interpreter over batched,
//!   cache-blocked kernels ([`ops`]), sharding large batches across a
//!   scoped worker pool sized by [`threads`] (`PROGNET_THREADS` /
//!   `--threads`, 0 = auto). Dependency-free, runs offline on any
//!   target; the crate default. A `reference-scalar` variant keeps the
//!   original per-sample loops as a benchmark/test oracle.
//! - `pjrt` (cargo feature `pjrt`) — the XLA/PJRT CPU client executing
//!   AOT HLO-text artifacts; interchange is HLO **text** because jax
//!   ≥ 0.5 emits serialized protos with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects — the text parser reassigns ids.
//! - [`Engine`] — process-wide backend handle + selection
//!   (`PROGNET_BACKEND`, `--backend`, or explicit constructors).
//! - [`ModelSession`] — per-model staged execution: feeds images plus a
//!   flat weight vector (or quantized planes for the fused-dequant path)
//!   into the compiled model.
//! - [`ApproxModel`] — a session plus a versioned, hot-swappable weight
//!   cell: the progressive client publishes each stage's reconstruction,
//!   readers serve inference from atomic snapshots mid-download.
//! - [`LayerGate`] / [`StreamStats`] ([`stream`]) — layer-granular
//!   streaming: the download publishes each layer's weights the moment
//!   they land, and a pipelined executor
//!   ([`CompiledModel::execute_streaming`]) blocks per layer on arrival,
//!   so inference begins once layer 0 is down.
//!
//! Weights are an *execute-time* input on purpose: §III-C inference runs
//! concurrently with the ongoing transmission, so every completed stage
//! re-executes the same compiled model with an improved reconstruction.

pub mod backend;
pub mod engine;
pub mod ops;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod session;
pub mod stream;

pub use backend::{Backend, CompiledModel};
pub use engine::Engine;
pub use reference::ReferenceBackend;
pub use session::{ApproxModel, ApproxOutput, InferOutput, ModelSession, WeightsVersion};
pub use stream::{LayerDispatch, LayerGate, LayerUpdate, StreamStats};

use crate::util::sync::atomic::{AtomicUsize, Ordering};

/// Explicit worker override set by [`set_threads`]; `usize::MAX` = unset.
static THREADS: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Set the process-wide worker count for batched execution (`--threads`
/// on the CLI, `threads` in the serve config; `0` = auto-size from
/// available parallelism). Takes precedence over `PROGNET_THREADS`.
///
/// Backends snapshot the resolved value when they are constructed, so
/// call this before building an [`Engine`]. Tests wanting a specific
/// count should prefer [`ReferenceBackend::with_threads`] over mutating
/// this process-wide knob.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::SeqCst);
}

/// Resolved worker count for batched execution, in precedence order:
/// explicit [`set_threads`] value, else `PROGNET_THREADS`, else one
/// worker per available core. Never returns 0.
pub fn threads() -> usize {
    let explicit = THREADS.load(Ordering::SeqCst);
    let n = if explicit != usize::MAX {
        explicit
    } else {
        std::env::var("PROGNET_THREADS")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(0)
    };
    if n == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        n
    }
}
