//! Pluggable inference runtime — executes progressive reconstructions on
//! the request path (python is never involved at runtime).
//!
//! The runtime is split into a small trait layer and interchangeable
//! backends:
//!
//! - [`Backend`] / [`CompiledModel`] — the compile / load-weights /
//!   execute contract every execution engine implements.
//! - [`ReferenceBackend`] — pure-Rust naive interpreter (matmul, conv,
//!   relu, softmax over the dequantized tensors). Dependency-free, runs
//!   offline on any target; the crate default.
//! - `pjrt` (cargo feature `pjrt`) — the XLA/PJRT CPU client executing
//!   AOT HLO-text artifacts; interchange is HLO **text** because jax
//!   ≥ 0.5 emits serialized protos with 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects — the text parser reassigns ids.
//! - [`Engine`] — process-wide backend handle + selection
//!   (`PROGNET_BACKEND`, `--backend`, or explicit constructors).
//! - [`ModelSession`] — per-model staged execution: feeds images plus a
//!   flat weight vector (or quantized planes for the fused-dequant path)
//!   into the compiled model.
//! - [`ApproxModel`] — a session plus a versioned, hot-swappable weight
//!   cell: the progressive client publishes each stage's reconstruction,
//!   readers serve inference from atomic snapshots mid-download.
//!
//! Weights are an *execute-time* input on purpose: §III-C inference runs
//! concurrently with the ongoing transmission, so every completed stage
//! re-executes the same compiled model with an improved reconstruction.

pub mod backend;
pub mod engine;
pub mod ops;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod session;

pub use backend::{Backend, CompiledModel};
pub use engine::Engine;
pub use reference::ReferenceBackend;
pub use session::{ApproxModel, ApproxOutput, InferOutput, ModelSession, WeightsVersion};
