//! Layer-granular streaming execution support: the [`LayerGate`]
//! hand-off between a progressive download and a pipelined forward pass.
//!
//! The paper's concurrency model overlaps transmission with inference at
//! stage granularity: infer with stage `k` while stage `k+1` streams.
//! A `LayerMajor`-annotated container (see [`crate::format::header`])
//! sharpens that to *layer* granularity — layer 0's stage-0 bits land
//! long before the rest of the stage, so the forward pass can start as
//! soon as the first layer's weights exist. The gate is the
//! synchronization point: the download side publishes each layer's
//! dequantized segment the moment the layer completes a stage
//! ([`LayerGate::publish_layer`]); the executor blocks per layer on
//! arrival ([`LayerGate::wait`]) and otherwise never synchronizes.
//!
//! Timestamps ride along with each publication, so an executor replaying
//! a virtual-time schedule (tests, benches) reports when each dispatch
//! *became possible* rather than when the executor thread happened to
//! run — that determinism is what `tests/layer_streaming.rs` pins.

#![forbid(unsafe_code)]

use std::ops::Range;

use crate::obs::{self, TraceCtx};
use crate::util::sync::{Condvar, Mutex};

/// What [`LayerGate::wait`] hands the executor: the newest published
/// state of one layer.
#[derive(Debug, Clone)]
pub struct LayerUpdate {
    /// highest stage this layer has fully absorbed
    pub stage: usize,
    /// publisher-supplied timestamp of that stage's arrival (seconds on
    /// the publisher's clock — virtual time in the test harness)
    pub t: f64,
    /// flat-weight element range the segment covers
    pub range: Range<usize>,
    /// dequantized weights for the layer at `stage`'s cumulative bits
    pub seg: Vec<f32>,
}

/// One layer's slot inside the gate.
#[derive(Debug, Default)]
struct Slot {
    /// stages published (+1 semantics; 0 = nothing yet)
    stages: usize,
    stage: usize,
    t: f64,
    range: Range<usize>,
    seg: Vec<f32>,
}

#[derive(Debug)]
struct GateState {
    slots: Vec<Slot>,
    closed: bool,
}

/// Rendezvous between a layer-granular download and a streaming
/// executor.
///
/// The publisher calls [`LayerGate::publish_layer`] once per completed
/// `(layer, stage)` — strictly in stage order per layer — and
/// [`LayerGate::close`] when the transfer ends (normally or not). The
/// executor calls [`LayerGate::wait`] per layer; it blocks until the
/// layer has at least the requested stage, and sees the *newest*
/// published stage (skip-to-latest, mirroring `InferencePolicy::LatestOnly`).
///
/// The gate snapshots each segment at publish time, so the executor
/// reads a consistent per-layer reconstruction even while the
/// assembler's flat buffer keeps mutating under later fragments.
#[derive(Debug)]
pub struct LayerGate {
    layers: usize,
    state: Mutex<GateState>,
    arrived: Condvar,
    /// parent context for `client.gate_wait` spans; set by the session
    /// driver when its request is traced, never touched otherwise
    trace: Mutex<Option<TraceCtx>>,
}

impl LayerGate {
    /// A gate for a model with `layers` annotated layers.
    pub fn new(layers: usize) -> Self {
        let slots = (0..layers).map(|_| Slot::default()).collect();
        Self {
            layers,
            state: Mutex::new(GateState {
                slots,
                closed: false,
            }),
            arrived: Condvar::new(),
            trace: Mutex::new(None),
        }
    }

    /// Parent every subsequent [`LayerGate::wait`] under `ctx` (the
    /// session's `client.request` span): each wait records a
    /// `client.gate_wait` child span covering its blocking time.
    pub fn set_trace(&self, ctx: TraceCtx) {
        *self.trace.lock().unwrap() = Some(ctx);
    }

    /// Number of layers the gate was sized for.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Publish layer `layer` at `stage`: `seg` is the layer's dequantized
    /// flat-weight segment covering `range`, `t` the arrival timestamp on
    /// the publisher's clock. Stages must be published in order per layer
    /// (the assembler's in-order absorption guarantees this; duplicates
    /// never re-emit). Publishing after [`LayerGate::close`] is a no-op.
    pub fn publish_layer(
        &self,
        layer: usize,
        stage: usize,
        t: f64,
        range: Range<usize>,
        seg: &[f32],
    ) {
        assert_eq!(seg.len(), range.len(), "segment/range size mismatch");
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return;
        }
        let slot = &mut st.slots[layer];
        assert_eq!(
            stage, slot.stages,
            "layer {layer}: stages must be published in order"
        );
        // lint:hot-path — the segment is snapshotted under the gate lock
        // so a waiting executor never observes a half-published layer;
        // `clear` + `extend` reuses the slot's allocation after the first
        // stage (see the lint-allow entry for this file)
        slot.seg.clear();
        slot.seg.extend_from_slice(seg);
        // lint:end-hot-path
        slot.stage = stage;
        slot.t = t;
        slot.range = range;
        slot.stages = stage + 1;
        drop(st);
        self.arrived.notify_all();
    }

    /// Block until `layer` has absorbed at least `min_stage`, then return
    /// its newest published state. Returns `None` once the gate is closed
    /// and the requirement can no longer be met.
    pub fn wait(&self, layer: usize, min_stage: usize) -> Option<LayerUpdate> {
        // With tracing disabled (the default) this is one atomic load —
        // the trace mutex is never even touched.
        let span = if obs::enabled() {
            self.trace.lock().unwrap().map(|ctx| {
                let mut sp = obs::begin_child("client.gate_wait", ctx);
                sp.attr("layer", layer);
                sp
            })
        } else {
            None
        };
        let update = self.wait_update(layer, min_stage);
        if let Some(mut sp) = span {
            if let Some(up) = &update {
                sp.attr("stage", up.stage);
            }
            sp.end();
        }
        update
    }

    fn wait_update(&self, layer: usize, min_stage: usize) -> Option<LayerUpdate> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.slots[layer].stages > min_stage {
                let slot = &st.slots[layer];
                // lint:hot-path — the per-wait snapshot copy keeps the
                // executor lock-free while it computes; the allocation is
                // waived for this file (see lint-allow.txt)
                return Some(LayerUpdate {
                    stage: slot.stage,
                    t: slot.t,
                    range: slot.range.clone(),
                    seg: slot.seg.to_vec(),
                });
                // lint:end-hot-path
            }
            if st.closed {
                return None;
            }
            st = self.arrived.wait(st).unwrap();
        }
    }

    /// Close the gate: wakes every waiter; [`LayerGate::wait`] calls that
    /// cannot be satisfied return `None` from now on. Idempotent. Call on
    /// every transfer exit path — otherwise a streaming executor waiting
    /// on an undelivered layer blocks forever.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.arrived.notify_all();
    }

    /// Whether [`LayerGate::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// One executed layer of a streaming forward pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerDispatch {
    pub layer: usize,
    /// the stage whose weights the layer ran with
    pub stage: usize,
    /// publish timestamp of that `(layer, stage)` — when the dispatch
    /// became *possible*, on the publisher's clock
    pub t: f64,
}

/// What a pipelined forward pass reports: the per-layer dispatch record,
/// in execution order.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub dispatches: Vec<LayerDispatch>,
}

impl StreamStats {
    /// When inference *began*: the publish time of the first executed
    /// layer. This is the streaming pipeline's time-to-first-inference —
    /// compute is free in virtual time, so TTFI is bounded by when layer
    /// 0's first stage finished transferring.
    pub fn t_first_dispatch(&self) -> f64 {
        self.dispatches.first().map(|d| d.t).unwrap_or(f64::NAN)
    }

    /// Publish time of the last executed layer — when the pipeline's
    /// final blocking wait was satisfied.
    pub fn t_last_dispatch(&self) -> f64 {
        self.dispatches.last().map(|d| d.t).unwrap_or(f64::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::Arc;

    #[test]
    fn publish_then_wait_returns_the_update() {
        let gate = LayerGate::new(2);
        gate.publish_layer(0, 0, 0.5, 4..8, &[1.0, 2.0, 3.0, 4.0]);
        let up = gate.wait(0, 0).unwrap();
        assert_eq!(up.stage, 0);
        assert_eq!(up.t, 0.5);
        assert_eq!(up.range, 4..8);
        assert_eq!(up.seg, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn wait_skips_to_the_newest_stage() {
        let gate = LayerGate::new(1);
        gate.publish_layer(0, 0, 0.1, 0..1, &[1.0]);
        gate.publish_layer(0, 1, 0.2, 0..1, &[2.0]);
        let up = gate.wait(0, 0).unwrap();
        assert_eq!((up.stage, up.t), (1, 0.2));
        assert_eq!(up.seg, vec![2.0]);
    }

    #[test]
    fn wait_blocks_until_publish() {
        let gate = Arc::new(LayerGate::new(1));
        let g2 = gate.clone();
        let waiter = std::thread::spawn(move || g2.wait(0, 1));
        // two stages must land before the waiter is satisfied
        gate.publish_layer(0, 0, 0.1, 0..1, &[1.0]);
        gate.publish_layer(0, 1, 0.2, 0..1, &[2.0]);
        let up = waiter.join().unwrap().unwrap();
        assert_eq!(up.stage, 1);
    }

    #[test]
    fn close_releases_unsatisfiable_waits() {
        let gate = Arc::new(LayerGate::new(2));
        gate.publish_layer(0, 0, 0.1, 0..1, &[1.0]);
        let g2 = gate.clone();
        let waiter = std::thread::spawn(move || g2.wait(1, 0));
        gate.close();
        assert!(waiter.join().unwrap().is_none());
        assert!(gate.is_closed());
        // satisfied waits still succeed after close
        assert_eq!(gate.wait(0, 0).unwrap().stage, 0);
        // and late publishes are dropped, not applied
        gate.publish_layer(1, 0, 0.2, 1..2, &[2.0]);
        assert!(gate.wait(1, 0).is_none());
    }

    #[test]
    #[should_panic(expected = "published in order")]
    fn out_of_order_publish_panics() {
        let gate = LayerGate::new(1);
        gate.publish_layer(0, 1, 0.1, 0..1, &[1.0]);
    }

    #[test]
    fn stats_report_first_and_last_dispatch() {
        let stats = StreamStats {
            dispatches: vec![
                LayerDispatch { layer: 0, stage: 0, t: 0.25 },
                LayerDispatch { layer: 1, stage: 0, t: 0.75 },
            ],
        };
        assert_eq!(stats.t_first_dispatch(), 0.25);
        assert_eq!(stats.t_last_dispatch(), 0.75);
        assert!(StreamStats::default().t_first_dispatch().is_nan());
    }
}
