//! Naive tensor primitives for the reference backend.
//!
//! Straightforward, allocation-light loops — the point is a correct,
//! dependency-free executor on any device, not peak throughput. Layouts
//! match the build-time JAX models (`python/compile/model.py`): activations
//! are NHWC, convolution weights are HWIO `[3, 3, cin, cout]`, dense
//! weights are `[cin, cout]`.

// The convolution takes every dimension explicitly rather than a shape
// struct — it mirrors the JAX op signature it reimplements.
#![allow(clippy::too_many_arguments)]

/// `y = x @ w + b` for one sample: `x` is `cin` floats, `w` is
/// `[cin, cout]` row-major, `b` is `cout` floats (or empty for a bias-free
/// layer). Writes `cout` floats into `out`.
pub fn dense(x: &[f32], w: &[f32], b: &[f32], cin: usize, cout: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), cin);
    debug_assert_eq!(w.len(), cin * cout);
    debug_assert_eq!(out.len(), cout);
    if b.is_empty() {
        out.fill(0.0);
    } else {
        out.copy_from_slice(b);
    }
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * cout..(i + 1) * cout];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
}

/// 3×3 SAME convolution over one NHWC sample with fused bias + ReLU.
///
/// `x` is `[h, w, cin]`, `wgt` is HWIO `[3, 3, cin, cout]`, `b` is `cout`
/// floats; writes `[h, w, cout]` into `out`. Mirrors the JAX
/// `conv_general_dilated(..., "SAME") + relu(x + b)` block.
pub fn conv3x3_same_bias_relu(
    x: &[f32],
    wgt: &[f32],
    b: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), h * w * cin);
    debug_assert_eq!(wgt.len(), 9 * cin * cout);
    debug_assert_eq!(b.len(), cout);
    debug_assert_eq!(out.len(), h * w * cout);
    for oy in 0..h {
        for ox in 0..w {
            let acc = &mut out[(oy * w + ox) * cout..(oy * w + ox + 1) * cout];
            acc.copy_from_slice(b);
            for ky in 0..3usize {
                let iy = oy as isize + ky as isize - 1;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..3usize {
                    let ix = ox as isize + kx as isize - 1;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let px = &x[((iy as usize) * w + ix as usize) * cin..][..cin];
                    let wk = &wgt[(ky * 3 + kx) * cin * cout..][..cin * cout];
                    for (ci, &xv) in px.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &wk[ci * cout..(ci + 1) * cout];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            for a in acc.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                }
            }
        }
    }
}

/// 2×2 max-pool, stride 2, VALID padding over one NHWC sample.
///
/// `x` is `[h, w, c]`; writes `[h/2, w/2, c]` into `out` (`h`, `w` even in
/// every supported architecture; a ragged last row/column is dropped,
/// matching VALID semantics).
pub fn maxpool2x2(x: &[f32], h: usize, w: usize, c: usize, out: &mut [f32]) {
    let oh = h / 2;
    let ow = w / 2;
    debug_assert_eq!(x.len(), h * w * c);
    debug_assert_eq!(out.len(), oh * ow * c);
    for py in 0..oh {
        for px in 0..ow {
            for ci in 0..c {
                let at = |y: usize, x_: usize| x[(y * w + x_) * c + ci];
                let m = at(2 * py, 2 * px)
                    .max(at(2 * py, 2 * px + 1))
                    .max(at(2 * py + 1, 2 * px))
                    .max(at(2 * py + 1, 2 * px + 1));
                out[(py * ow + px) * c + ci] = m;
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Numerically stable in-place softmax over one row.
pub fn softmax(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Logistic sigmoid.
pub fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_manual() {
        // x [2], w [2,3], b [3]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5, -0.5, 0.0];
        let mut out = [0.0; 3];
        dense(&x, &w, &b, 2, 3, &mut out);
        assert_eq!(out, [1.0 + 8.0 + 0.5, 2.0 + 10.0 - 0.5, 3.0 + 12.0]);
    }

    #[test]
    fn dense_without_bias() {
        let x = [2.0];
        let w = [3.0, -1.0];
        let mut out = [9.9; 2];
        dense(&x, &w, &[], 1, 2, &mut out);
        assert_eq!(out, [6.0, -2.0]);
    }

    #[test]
    fn conv_identity_kernel_is_relu_passthrough() {
        // 1-channel 4x4 input, kernel = 1 at center, bias 0 → relu(x)
        let h = 4;
        let w = 4;
        let x: Vec<f32> = (0..16).map(|i| i as f32 - 7.5).collect();
        let mut wgt = vec![0.0f32; 9];
        wgt[4] = 1.0; // center tap (ky=1, kx=1)
        let mut out = vec![0.0f32; 16];
        conv3x3_same_bias_relu(&x, &wgt, &[0.0], h, w, 1, 1, &mut out);
        for (o, &xi) in out.iter().zip(&x) {
            assert_eq!(*o, xi.max(0.0));
        }
    }

    #[test]
    fn conv_same_padding_sums_neighbourhood() {
        // all-ones 3x3 kernel over an all-ones 3x3 input counts the valid
        // neighbours: corners 4, edges 6, center 9.
        let x = vec![1.0f32; 9];
        let wgt = vec![1.0f32; 9];
        let mut out = vec![0.0f32; 9];
        conv3x3_same_bias_relu(&x, &wgt, &[0.0], 3, 3, 1, 1, &mut out);
        assert_eq!(out, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn maxpool_picks_max() {
        // 2x2x1 windows over 4x2 input
        let x = vec![1.0, 5.0, 2.0, 0.0, -3.0, -1.0, -2.0, -9.0];
        let mut out = vec![0.0; 2];
        maxpool2x2(&x, 4, 2, 1, &mut out);
        assert_eq!(out, vec![5.0, -1.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut row = [1.0f32, 2.0, 3.0];
        softmax(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
        // stability: huge logits must not overflow
        let mut big = [1000.0f32, 1000.0];
        softmax(&mut big);
        assert!((big[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn sigmoid_range_and_symmetry() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!(sigmoid(10.0) > 0.999);
        assert!(sigmoid(-10.0) < 0.001);
        assert!((sigmoid(1.3) + sigmoid(-1.3) - 1.0).abs() < 1e-6);
    }
}
