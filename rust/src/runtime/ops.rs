//! Tensor primitives for the reference backend: batched, cache-blocked
//! fast-path kernels plus the original scalar loops kept as oracles.
//!
//! Layouts match the build-time JAX models (`python/compile/model.py`):
//! activations are NHWC, convolution weights are HWIO `[3, 3, cin, cout]`,
//! dense weights are `[cin, cout]`, all row-major.
//!
//! # Blocked-kernel layout
//!
//! The hot path is [`matmul_bias_relu`]: `Y[n, cout] = X[n, cin] @
//! W[cin, cout] (+ b)`, built around an `MR × NR` (4 × 16) register
//! micro-kernel. Each weight row `W[i, j..j+NR]` is streamed from
//! memory **once per row block** and feeds four accumulator rows that
//! live in vector registers across the whole `cin` reduction — the
//! inner loop is a branch-free, bounds-check-free chain of mul-adds the
//! compiler auto-vectorizes.
//! Convolution rides the same kernel: [`im2col3x3`] scatters each NHWC
//! sample into 3×3-patch rows (`(ky, kx, ci)` order — exactly the HWIO
//! weight layout), turning `conv3x3 + bias + ReLU` into one
//! `[n·h·w, 9·cin] @ [9·cin, cout]` matmul.
//!
//! Accumulation order over the reduction dimension is identical between
//! the fast kernels and the scalar oracles ([`dense`],
//! [`conv3x3_same_bias_relu`]), so their outputs are bit-equal — the
//! equivalence tests in `tests/runtime_fastpath.rs` assert exact
//! equality, not tolerances.

// The convolution takes every dimension explicitly rather than a shape
// struct — it mirrors the JAX op signature it reimplements.
#![allow(clippy::too_many_arguments)]

/// Batch rows per register tile of [`matmul_bias_relu`].

#![forbid(unsafe_code)]
const MR: usize = 4;
/// Output columns per register tile: `MR × NR` f32 accumulators live in
/// vector registers across the whole `cin` reduction.
const NR: usize = 16;

/// Batched `Y = X @ W (+ b)` with optionally fused ReLU.
///
/// `x` is `[n, cin]` row-major, `w` is `[cin, cout]` row-major, `b` is
/// `cout` floats (or empty for a bias-free layer); writes `[n, cout]`
/// into `out`. The core is an `MR × NR` register micro-kernel: each
/// weight row is streamed from memory once per `MR` batch rows, and the
/// accumulator tile stays in registers across the whole reduction —
/// fixed-size arrays keep the inner loop free of bounds checks so it
/// auto-vectorizes. Ragged row/column remainders fall back to plain
/// accumulation. Bit-equal to running [`dense`] (+ [`relu`]) per row:
/// both accumulate over `cin` in ascending order.
pub fn matmul_bias_relu(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n: usize,
    cin: usize,
    cout: usize,
    fuse_relu: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), n * cin);
    debug_assert_eq!(w.len(), cin * cout);
    debug_assert_eq!(out.len(), n * cout);
    debug_assert!(b.is_empty() || b.len() == cout);
    // lint:hot-path — the whole kernel works in caller-provided buffers
    for row in out.chunks_exact_mut(cout) {
        if b.is_empty() {
            row.fill(0.0);
        } else {
            row.copy_from_slice(b);
        }
    }
    let jtiles = cout / NR * NR;
    let mut r = 0;
    while r + MR <= n {
        let xrows: [&[f32]; MR] = [
            &x[r * cin..(r + 1) * cin],
            &x[(r + 1) * cin..(r + 2) * cin],
            &x[(r + 2) * cin..(r + 3) * cin],
            &x[(r + 3) * cin..(r + 4) * cin],
        ];
        // MR × NR register tile: load (bias-initialised), reduce, store
        let mut j0 = 0;
        while j0 < jtiles {
            let mut acc = [[0f32; NR]; MR];
            for (rr, a) in acc.iter_mut().enumerate() {
                a.copy_from_slice(&out[(r + rr) * cout + j0..][..NR]);
            }
            for i in 0..cin {
                let wr: &[f32; NR] = w[i * cout + j0..i * cout + j0 + NR]
                    .try_into()
                    .expect("NR-wide tile");
                for (rr, a) in acc.iter_mut().enumerate() {
                    let xv = xrows[rr][i];
                    for c in 0..NR {
                        a[c] += xv * wr[c];
                    }
                }
            }
            for (rr, a) in acc.iter().enumerate() {
                out[(r + rr) * cout + j0..][..NR].copy_from_slice(a);
            }
            j0 += NR;
        }
        // ragged column tail for these MR rows
        if jtiles < cout {
            let (o0, rest) = out[r * cout..(r + MR) * cout].split_at_mut(cout);
            let (o1, rest) = rest.split_at_mut(cout);
            let (o2, o3) = rest.split_at_mut(cout);
            for i in 0..cin {
                let (x0, x1, x2, x3) = (xrows[0][i], xrows[1][i], xrows[2][i], xrows[3][i]);
                let wrow = &w[i * cout + jtiles..(i + 1) * cout];
                for ((((&wv, v0), v1), v2), v3) in wrow
                    .iter()
                    .zip(o0[jtiles..].iter_mut())
                    .zip(o1[jtiles..].iter_mut())
                    .zip(o2[jtiles..].iter_mut())
                    .zip(o3[jtiles..].iter_mut())
                {
                    *v0 += x0 * wv;
                    *v1 += x1 * wv;
                    *v2 += x2 * wv;
                    *v3 += x3 * wv;
                }
            }
        }
        r += MR;
    }
    // ragged tail rows (n % MR): plain one-row accumulation
    for r in r..n {
        let o = &mut out[r * cout..(r + 1) * cout];
        let xr = &x[r * cin..(r + 1) * cin];
        for (i, &xi) in xr.iter().enumerate() {
            let wrow = &w[i * cout..(i + 1) * cout];
            for (a, &wv) in o.iter_mut().zip(wrow) {
                *a += xi * wv;
            }
        }
    }
    if fuse_relu {
        relu(out);
    }
    // lint:end-hot-path
}

/// Scatter one NHWC sample into 3×3-patch rows ("im2col").
///
/// Row `oy*w + ox` of `col` holds the `9*cin` inputs under the kernel
/// window centred at `(oy, ox)`, in `(ky, kx, ci)` order — the same
/// order HWIO weights `[3, 3, cin, cout]` are laid out — with zeros
/// where SAME padding falls outside the image. `x` is `[h, w, cin]`,
/// `col` must be `h*w*9*cin` long. A conv layer is then one
/// [`matmul_bias_relu`] over the patch rows.
pub fn im2col3x3(x: &[f32], h: usize, w: usize, cin: usize, col: &mut [f32]) {
    let patch = 9 * cin;
    debug_assert_eq!(x.len(), h * w * cin);
    debug_assert_eq!(col.len(), h * w * patch);
    for oy in 0..h {
        for ky in 0..3usize {
            let iy = oy as isize + ky as isize - 1;
            if iy < 0 || iy >= h as isize {
                // the whole ky tap row is padding for every ox
                for ox in 0..w {
                    col[(oy * w + ox) * patch + ky * 3 * cin..][..3 * cin].fill(0.0);
                }
                continue;
            }
            let xrow = &x[(iy as usize) * w * cin..][..w * cin];
            for ox in 0..w {
                let dst = &mut col[(oy * w + ox) * patch + ky * 3 * cin..][..3 * cin];
                for kx in 0..3usize {
                    let ix = ox as isize + kx as isize - 1;
                    let d = &mut dst[kx * cin..(kx + 1) * cin];
                    if ix < 0 || ix >= w as isize {
                        d.fill(0.0);
                    } else {
                        d.copy_from_slice(&xrow[(ix as usize) * cin..][..cin]);
                    }
                }
            }
        }
    }
}

/// `y = x @ w + b` for one sample: `x` is `cin` floats, `w` is
/// `[cin, cout]` row-major, `b` is `cout` floats (or empty for a bias-free
/// layer). Writes `cout` floats into `out`.
///
/// Scalar oracle for [`matmul_bias_relu`] — kept (and tested against the
/// batched kernel) rather than deleted, and still used for 1-sample
/// remainders where tiling buys nothing.
pub fn dense(x: &[f32], w: &[f32], b: &[f32], cin: usize, cout: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), cin);
    debug_assert_eq!(w.len(), cin * cout);
    debug_assert_eq!(out.len(), cout);
    if b.is_empty() {
        out.fill(0.0);
    } else {
        out.copy_from_slice(b);
    }
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * cout..(i + 1) * cout];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
}

/// 3×3 SAME convolution over one NHWC sample with fused bias + ReLU.
///
/// `x` is `[h, w, cin]`, `wgt` is HWIO `[3, 3, cin, cout]`, `b` is `cout`
/// floats; writes `[h, w, cout]` into `out`. Mirrors the JAX
/// `conv_general_dilated(..., "SAME") + relu(x + b)` block.
///
/// Scalar oracle for the [`im2col3x3`] + [`matmul_bias_relu`] fast path;
/// accumulation order over `(ky, kx, ci)` matches it exactly.
pub fn conv3x3_same_bias_relu(
    x: &[f32],
    wgt: &[f32],
    b: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), h * w * cin);
    debug_assert_eq!(wgt.len(), 9 * cin * cout);
    debug_assert_eq!(b.len(), cout);
    debug_assert_eq!(out.len(), h * w * cout);
    for oy in 0..h {
        for ox in 0..w {
            let acc = &mut out[(oy * w + ox) * cout..(oy * w + ox + 1) * cout];
            acc.copy_from_slice(b);
            for ky in 0..3usize {
                let iy = oy as isize + ky as isize - 1;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..3usize {
                    let ix = ox as isize + kx as isize - 1;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let px = &x[((iy as usize) * w + ix as usize) * cin..][..cin];
                    let wk = &wgt[(ky * 3 + kx) * cin * cout..][..cin * cout];
                    for (ci, &xv) in px.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let wrow = &wk[ci * cout..(ci + 1) * cout];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            for a in acc.iter_mut() {
                if *a < 0.0 {
                    *a = 0.0;
                }
            }
        }
    }
}

/// 2×2 max-pool, stride 2, VALID padding over one NHWC sample.
///
/// `x` is `[h, w, c]`; writes `[h/2, w/2, c]` into `out` (`h`, `w` even in
/// every supported architecture; a ragged last row/column is dropped,
/// matching VALID semantics).
pub fn maxpool2x2(x: &[f32], h: usize, w: usize, c: usize, out: &mut [f32]) {
    let oh = h / 2;
    let ow = w / 2;
    debug_assert_eq!(x.len(), h * w * c);
    debug_assert_eq!(out.len(), oh * ow * c);
    for py in 0..oh {
        for px in 0..ow {
            for ci in 0..c {
                let at = |y: usize, x_: usize| x[(y * w + x_) * c + ci];
                let m = at(2 * py, 2 * px)
                    .max(at(2 * py, 2 * px + 1))
                    .max(at(2 * py + 1, 2 * px))
                    .max(at(2 * py + 1, 2 * px + 1));
                out[(py * ow + px) * c + ci] = m;
            }
        }
    }
}

/// In-place ReLU.
pub fn relu(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Numerically stable in-place softmax over one row.
pub fn softmax(row: &mut [f32]) {
    if row.is_empty() {
        return;
    }
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Logistic sigmoid.
pub fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_manual() {
        // x [2], w [2,3], b [3]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5, -0.5, 0.0];
        let mut out = [0.0; 3];
        dense(&x, &w, &b, 2, 3, &mut out);
        assert_eq!(out, [1.0 + 8.0 + 0.5, 2.0 + 10.0 - 0.5, 3.0 + 12.0]);
    }

    #[test]
    fn dense_without_bias() {
        let x = [2.0];
        let w = [3.0, -1.0];
        let mut out = [9.9; 2];
        dense(&x, &w, &[], 1, 2, &mut out);
        assert_eq!(out, [6.0, -2.0]);
    }

    #[test]
    fn conv_identity_kernel_is_relu_passthrough() {
        // 1-channel 4x4 input, kernel = 1 at center, bias 0 → relu(x)
        let h = 4;
        let w = 4;
        let x: Vec<f32> = (0..16).map(|i| i as f32 - 7.5).collect();
        let mut wgt = vec![0.0f32; 9];
        wgt[4] = 1.0; // center tap (ky=1, kx=1)
        let mut out = vec![0.0f32; 16];
        conv3x3_same_bias_relu(&x, &wgt, &[0.0], h, w, 1, 1, &mut out);
        for (o, &xi) in out.iter().zip(&x) {
            assert_eq!(*o, xi.max(0.0));
        }
    }

    #[test]
    fn conv_same_padding_sums_neighbourhood() {
        // all-ones 3x3 kernel over an all-ones 3x3 input counts the valid
        // neighbours: corners 4, edges 6, center 9.
        let x = vec![1.0f32; 9];
        let wgt = vec![1.0f32; 9];
        let mut out = vec![0.0f32; 9];
        conv3x3_same_bias_relu(&x, &wgt, &[0.0], 3, 3, 1, 1, &mut out);
        assert_eq!(out, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn maxpool_picks_max() {
        // 2x2x1 windows over 4x2 input
        let x = vec![1.0, 5.0, 2.0, 0.0, -3.0, -1.0, -2.0, -9.0];
        let mut out = vec![0.0; 2];
        maxpool2x2(&x, 4, 2, 1, &mut out);
        assert_eq!(out, vec![5.0, -1.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut row = [1.0f32, 2.0, 3.0];
        softmax(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
        // stability: huge logits must not overflow
        let mut big = [1000.0f32, 1000.0];
        softmax(&mut big);
        assert!((big[0] - 0.5).abs() < 1e-6);
    }

    fn seeded(seed: u64, n: usize) -> Vec<f32> {
        let mut r = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| r.normal_ms(0.0, 0.6) as f32).collect()
    }

    #[test]
    fn matmul_matches_dense_oracle_exactly() {
        // ragged n exercises both the MR-row tile and the tail path;
        // cout values straddle the NR=16 column tile (8 = tail only,
        // 32 = tiles only, 1100 = 68 tiles + ragged 12)
        for (n, cin, cout) in
            [(1usize, 5usize, 3usize), (4, 8, 8), (5, 6, 32), (7, 16, 10), (9, 3, 1100)]
        {
            let x = seeded(n as u64 * 31 + cin as u64, n * cin);
            let w = seeded(cout as u64, cin * cout);
            let b = seeded(7, cout);
            let mut fast = vec![0f32; n * cout];
            matmul_bias_relu(&x, &w, &b, n, cin, cout, false, &mut fast);
            let mut slow = vec![0f32; cout];
            for r in 0..n {
                dense(&x[r * cin..(r + 1) * cin], &w, &b, cin, cout, &mut slow);
                assert_eq!(&fast[r * cout..(r + 1) * cout], &slow[..], "row {r}");
            }
        }
    }

    #[test]
    fn matmul_fused_relu_and_empty_bias() {
        let (n, cin, cout) = (6, 4, 5);
        let x = seeded(1, n * cin);
        let w = seeded(2, cin * cout);
        let mut with = vec![0f32; n * cout];
        matmul_bias_relu(&x, &w, &[], n, cin, cout, true, &mut with);
        let mut plain = vec![0f32; n * cout];
        matmul_bias_relu(&x, &w, &[], n, cin, cout, false, &mut plain);
        relu(&mut plain);
        assert_eq!(with, plain);
        assert!(with.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn im2col_matmul_matches_conv_oracle_exactly() {
        for (h, w, cin, cout) in [(4usize, 4usize, 1usize, 3usize), (5, 3, 2, 4), (6, 6, 3, 2)] {
            let x = seeded(h as u64 * 100 + w as u64, h * w * cin);
            let wgt = seeded(3, 9 * cin * cout);
            let b = seeded(4, cout);
            let mut oracle = vec![0f32; h * w * cout];
            conv3x3_same_bias_relu(&x, &wgt, &b, h, w, cin, cout, &mut oracle);
            let mut col = vec![0f32; h * w * 9 * cin];
            im2col3x3(&x, h, w, cin, &mut col);
            let mut fast = vec![0f32; h * w * cout];
            matmul_bias_relu(&col, &wgt, &b, h * w, 9 * cin, cout, true, &mut fast);
            assert_eq!(fast, oracle, "{h}x{w} cin={cin} cout={cout}");
        }
    }

    #[test]
    fn im2col_center_patch_is_neighbourhood() {
        // 3x3 single-channel image: the center output row is the whole
        // image in scan order; the corner row has padding zeros.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut col = vec![f32::NAN; 9 * 9];
        im2col3x3(&x, 3, 3, 1, &mut col);
        assert_eq!(&col[4 * 9..5 * 9], &x[..]);
        assert_eq!(&col[..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 4.0, 5.0]);
    }

    #[test]
    fn sigmoid_range_and_symmetry() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!(sigmoid(10.0) > 0.999);
        assert!(sigmoid(-10.0) < 0.001);
        assert!((sigmoid(1.3) + sigmoid(-1.3) - 1.0).abs() < 1e-6);
    }
}
