//! Admission control: a global connection cap with configurable
//! load-shedding policies.
//!
//! The accept loop consults [`Admission::on_accept`] for every new
//! connection. Under the cap the connection is admitted; over it the
//! configured [`ShedPolicy`] decides between rejecting immediately (an
//! `ERR` status frame naming [`SHED_MARKER`], so load generators can
//! distinguish shedding from protocol failures), parking the connection
//! in a bounded-wait queue, or admitting it *degraded* — its stage
//! windows are clamped to a few coarse stages, trading refinement for
//! service. Degrading is the shedding action unique to progressive
//! containers: every admitted client still reaches `ModelReady`, just at
//! lower precision.

#![forbid(unsafe_code)]

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use anyhow::{bail, Result};

/// Substring of the `ERR` status frame sent to shed connections.
/// `fleet::loadgen` classifies session errors containing it as
/// [`Outcome::Shed`](crate::fleet::slo::Outcome) rather than protocol
/// errors.
pub const SHED_MARKER: &str = "at capacity";

/// What to do with a connection that arrives over the cap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShedPolicy {
    /// Answer with an `ERR … at capacity` frame and close.
    Reject,
    /// Park the connection; serve it when a slot frees, shed it when the
    /// deadline passes first.
    Queue { deadline: Duration },
    /// Admit it anyway, but clamp initial stage windows to at most
    /// `max_stages` stages (≥ 1).
    Degrade { max_stages: u32 },
}

impl ShedPolicy {
    /// Parse the CLI/config forms: `reject`, `queue:<ms>`, `degrade:<stages>`.
    pub fn parse(text: &str) -> Result<Self> {
        let (head, arg) = match text.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (text, None),
        };
        match (head, arg) {
            ("reject", None) => Ok(Self::Reject),
            ("queue", Some(ms)) => {
                let ms: u64 = ms
                    .parse()
                    .map_err(|_| anyhow::anyhow!("queue deadline must be ms, got '{ms}'"))?;
                Ok(Self::Queue {
                    deadline: Duration::from_millis(ms),
                })
            }
            ("degrade", Some(k)) => {
                let k: u32 = k
                    .parse()
                    .map_err(|_| anyhow::anyhow!("degrade stage cap must be an int, got '{k}'"))?;
                if k == 0 {
                    bail!("degrade stage cap must be >= 1");
                }
                Ok(Self::Degrade { max_stages: k })
            }
            _ => bail!(
                "unknown shed policy '{text}' (expected reject | queue:<ms> | degrade:<stages>)"
            ),
        }
    }
}

impl std::fmt::Display for ShedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Reject => write!(f, "reject"),
            Self::Queue { deadline } => write!(f, "queue:{}", deadline.as_millis()),
            Self::Degrade { max_stages } => write!(f, "degrade:{max_stages}"),
        }
    }
}

/// Outcome of an admission check for one new connection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Under the cap; a slot was claimed (release it when the conn ends).
    Admit,
    /// Over the cap, degrade policy: serve with clamped stage windows.
    /// No slot is held — degraded conns are the overflow.
    Degrade { max_stages: u32 },
    /// Over the cap, queue policy: park until a slot frees or `deadline`.
    Queue { deadline: Duration },
    /// Over the cap, reject policy: shed now.
    Reject,
}

/// Global (cross-shard) admission state.
#[derive(Debug)]
pub struct Admission {
    cap: Option<usize>,
    policy: ShedPolicy,
    in_cap: AtomicUsize,
}

impl Admission {
    pub fn new(cap: Option<usize>, policy: ShedPolicy) -> Self {
        Self {
            cap,
            policy,
            in_cap: AtomicUsize::new(0),
        }
    }

    /// Claim a slot if one is free.
    pub fn try_admit(&self) -> bool {
        let Some(cap) = self.cap else {
            return true;
        };
        self.in_cap
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n < cap {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Admission decision for a newly accepted connection.
    pub fn on_accept(&self) -> Decision {
        if self.try_admit() {
            return Decision::Admit;
        }
        match self.policy {
            ShedPolicy::Reject => Decision::Reject,
            ShedPolicy::Queue { deadline } => Decision::Queue { deadline },
            ShedPolicy::Degrade { max_stages } => Decision::Degrade { max_stages },
        }
    }

    /// Release a slot claimed by [`Admission::try_admit`] /
    /// [`Decision::Admit`].
    pub fn release(&self) {
        if self.cap.is_some() {
            self.in_cap.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Currently claimed in-cap slots (diagnostics).
    pub fn in_cap(&self) -> usize {
        self.in_cap.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policies() {
        assert_eq!(ShedPolicy::parse("reject").unwrap(), ShedPolicy::Reject);
        assert_eq!(
            ShedPolicy::parse("queue:250").unwrap(),
            ShedPolicy::Queue {
                deadline: Duration::from_millis(250)
            }
        );
        assert_eq!(
            ShedPolicy::parse("degrade:3").unwrap(),
            ShedPolicy::Degrade { max_stages: 3 }
        );
        assert!(ShedPolicy::parse("degrade:0").is_err());
        assert!(ShedPolicy::parse("queue").is_err());
        assert!(ShedPolicy::parse("nope").is_err());
        // round-trips through Display
        for p in ["reject", "queue:250", "degrade:3"] {
            assert_eq!(ShedPolicy::parse(p).unwrap().to_string(), p);
        }
    }

    #[test]
    fn cap_claims_and_releases() {
        let a = Admission::new(Some(2), ShedPolicy::Reject);
        assert_eq!(a.on_accept(), Decision::Admit);
        assert_eq!(a.on_accept(), Decision::Admit);
        assert_eq!(a.on_accept(), Decision::Reject);
        a.release();
        assert_eq!(a.on_accept(), Decision::Admit);
        assert_eq!(a.in_cap(), 2);
    }

    #[test]
    fn uncapped_always_admits() {
        let a = Admission::new(None, ShedPolicy::Reject);
        for _ in 0..100 {
            assert_eq!(a.on_accept(), Decision::Admit);
        }
        // release on an uncapped admission is a no-op, not an underflow
        a.release();
        assert_eq!(a.in_cap(), 0);
    }

    #[test]
    fn over_cap_policy_selects_decision() {
        let q = Admission::new(
            Some(0),
            ShedPolicy::Queue {
                deadline: Duration::from_millis(9),
            },
        );
        assert_eq!(
            q.on_accept(),
            Decision::Queue {
                deadline: Duration::from_millis(9)
            }
        );
        let d = Admission::new(Some(0), ShedPolicy::Degrade { max_stages: 2 });
        assert_eq!(d.on_accept(), Decision::Degrade { max_stages: 2 });
    }
}
