//! Edge node: a v2-protocol server that caches **stage prefixes** and
//! relays the rest from an origin.
//!
//! The progressive container makes a uniquely cheap edge cache possible:
//! because any byte prefix covering the first `k` stages is a usable
//! approximate model, an edge that holds only stages `[0, k)` (a few
//! percent of the container) can serve the latency-critical head of
//! every fetch locally — TTFI traffic never leaves the edge — while the
//! long tail streams from the origin over the same stage-range protocol
//! the clients speak.
//!
//! Serving math per request (all offsets are absolute container bytes):
//!
//! ```text
//! sel        = body_range(req.stages)         selected body
//! serve_from = sel.start + req.offset         resume point
//! cached     = serve_from .. min(prefix_len, sel.end)   from the cache
//! tail       = cached.end .. sel.end                    relayed from origin
//! ```
//!
//! The client sees one status frame and one contiguous body — it cannot
//! tell an edge from an origin (property-tested for bit-identity in
//! `tests/cluster_serving.rs`).
//!
//! Cache fills are **single-flight** ([`crate::util::flight`]): a cold
//! stampede on one model performs exactly one origin fill. A fill is a
//! two-step fetch on one keep-alive connection — stages `[0, 1)` first
//! (never clamped by origin admission degrade, which guarantees at least
//! one stage), learn the stage count from the manifest, then `[1, k)` —
//! and the assembled prefix is re-validated frame-by-frame (CRC) before
//! it is published. If an origin's `container` length ever disagrees
//! with the cached entry (model re-encoded), the entry is invalidated
//! and the request retried against a fresh fill.
//!
//! Concurrency model: blocking sockets, one thread per connection with a
//! small stack. That is deliberately simpler than the origin's sharded
//! reactor — an edge's fan-in is bounded by the router in front of it,
//! and the relay path spends its life blocked on two sockets anyway.

#![forbid(unsafe_code)]

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::format::{validated_prefix, FrameParser, StageIndex};
use crate::netsim::{LinkSpec, ThrottledWriter};
use crate::obs::{self, TraceCtx};
use crate::server::proto::{self, FetchRequest, FetchResponse};
use crate::server::service::{open_fetch, request_on};
use crate::util::flight::SingleFlight;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;

use super::placement::{HashRing, DEFAULT_VNODES};
use super::ServerStats;

/// Cache key: model name + requested schedule widths (None = origin
/// default). Mirrors the origin repository's encoding key, so an edge
/// never serves a prefix encoded under a different schedule.
type Key = (String, Option<Vec<u32>>);

/// Edge configuration.
#[derive(Debug, Clone)]
pub struct EdgeConfig {
    /// stages `[0, prefix_stages)` are cached; clamped per model to its
    /// actual stage count
    pub prefix_stages: u32,
    /// shaping for origin-side fetches (None = unshaped); client-side
    /// shaping always honours the client's own `speed_mbps`
    pub origin_speed_mbps: Option<f64>,
    /// per-socket read timeout so handler threads cannot outlive a hung
    /// peer forever
    pub io_timeout: Duration,
}

impl Default for EdgeConfig {
    fn default() -> Self {
        Self {
            prefix_stages: 2,
            origin_speed_mbps: None,
            io_timeout: Duration::from_secs(10),
        }
    }
}

/// One cached, validated stage prefix of a container.
struct PrefixEntry {
    /// container bytes `[0, prefix_len)`: preamble + stages `[0, k)`,
    /// where k is `prefix_stages` clamped to the model's stage count
    bytes: Vec<u8>,
    index: StageIndex,
    prefix_len: usize,
    container_len: u64,
}

/// Running edge node (shuts down on drop).
pub struct Edge {
    addr: SocketAddr,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

struct Inner {
    origins: Vec<SocketAddr>,
    ring: HashRing,
    cfg: EdgeConfig,
    cache: SingleFlight<Key, Arc<PrefixEntry>>,
    stats: Arc<ServerStats>,
}

impl Edge {
    /// Bind `addr` (use `"127.0.0.1:0"` for ephemeral) and serve,
    /// fetching misses from `origins` (selected per model via the same
    /// consistent-hash placement the router uses).
    pub fn start(addr: &str, origins: Vec<SocketAddr>, cfg: EdgeConfig) -> Result<Self> {
        anyhow::ensure!(!origins.is_empty(), "edge needs at least one origin");
        anyhow::ensure!(cfg.prefix_stages >= 1, "prefix_stages must be >= 1");
        let listener = TcpListener::bind(addr).context("binding edge listener")?;
        let local = listener.local_addr()?;
        let stats = Arc::new(ServerStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let labels: Vec<String> = (0..origins.len()).map(|i| format!("origin-{i}")).collect();
        let inner = Arc::new(Inner {
            ring: HashRing::new(&labels, DEFAULT_VNODES),
            origins,
            cfg,
            cache: SingleFlight::new(),
            stats: stats.clone(),
        });
        let accept = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("prognet-edge-accept".into())
                .spawn(move || accept_loop(listener, inner, stop))?
        };
        Ok(Self {
            addr: local,
            stats,
            stop,
            accept: Some(accept),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &Arc<ServerStats> {
        &self.stats
    }

    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Edge {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<Inner>, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        inner.stats.connections.fetch_add(1, Ordering::SeqCst);
        inner.stats.active.fetch_add(1, Ordering::SeqCst);
        let inner = inner.clone();
        // small stacks: a handler is two sockets and a 16 KB relay buffer
        let spawned = std::thread::Builder::new()
            .name("prognet-edge-conn".into())
            .stack_size(256 * 1024)
            .spawn(move || {
                let stats = inner.stats.clone();
                if serve_conn(stream, &inner).is_err() {
                    stats.errors.fetch_add(1, Ordering::SeqCst);
                }
                stats.active.fetch_sub(1, Ordering::SeqCst);
            });
        if spawned.is_err() {
            inner.stats.errors.fetch_add(1, Ordering::SeqCst);
            inner.stats.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Serve one client connection until it closes or a request declines
/// keep-alive. A clean EOF before any request (health probe) is Ok.
fn serve_conn(mut stream: TcpStream, inner: &Inner) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(inner.cfg.io_timeout))?;
    loop {
        let req = match proto::read_request(&mut stream) {
            Ok(req) => req,
            // EOF / reset between requests is how clients (and the
            // router's health prober) hang up — not an error
            Err(_) => return Ok(()),
        };
        inner.stats.requests.fetch_add(1, Ordering::SeqCst);
        let keep_alive = req.keep_alive;
        // per-request span, parented on the client's wire-carried context;
        // RAII closes it on every path out of this iteration
        let mut req_span = req.trace.map(|ctx| obs::begin_child("edge.request", ctx));
        if let Some(sp) = req_span.as_mut() {
            sp.attr("model", &req.model);
        }
        let span_ctx = req_span.as_ref().map(|sp| sp.ctx());
        if let Some(verb) = req.verb.as_deref() {
            match verb {
                "stats" => serve_stats(&mut stream, &inner.stats)?,
                other => {
                    let _ = proto::write_err(&mut stream, &format!("unknown verb '{other}'"));
                    bail!("unknown verb '{other}'");
                }
            }
            if !keep_alive {
                return Ok(());
            }
            continue;
        }
        match serve_request(&mut stream, inner, &req, span_ctx) {
            Ok(()) => {}
            Err(e) => {
                // best effort: the client may already be gone
                let _ = proto::write_err(&mut stream, &format!("{e:#}"));
                bail!("serving {}: {e:#}", req.model);
            }
        }
        if !keep_alive {
            return Ok(());
        }
    }
}

/// Answer a `stats` verb with the metrics exposition as the raw body.
fn serve_stats(stream: &mut TcpStream, stats: &ServerStats) -> Result<()> {
    let body = obs::exposition(&[("edge", stats)], &[]).into_bytes();
    proto::write_ok(
        stream,
        &FetchResponse {
            total: body.len() as u64,
            remaining: body.len() as u64,
            container_len: body.len() as u64,
            stages: None,
        },
    )?;
    stream.write_all(&body)?;
    Ok(())
}

fn serve_request(
    stream: &mut TcpStream,
    inner: &Inner,
    req: &FetchRequest,
    span: Option<TraceCtx>,
) -> Result<()> {
    // one retry after invalidating a stale entry (origin re-encoded)
    match serve_attempt(stream, inner, req, span) {
        Err(e) if e.to_string().contains(STALE_MARKER) => {
            inner.cache.invalidate(&cache_key(req));
            serve_attempt(stream, inner, req, span)
        }
        other => other,
    }
}

/// Error marker for a cached prefix that no longer matches the origin's
/// container (checked against the tail fetch's `container` field).
const STALE_MARKER: &str = "edge cache stale";

fn cache_key(req: &FetchRequest) -> Key {
    (
        req.model.clone(),
        req.schedule.as_ref().map(|s| s.widths().to_vec()),
    )
}

fn serve_attempt(
    stream: &mut TcpStream,
    inner: &Inner,
    req: &FetchRequest,
    span: Option<TraceCtx>,
) -> Result<()> {
    let entry = inner
        .cache
        .get_or_compute(cache_key(req), || {
            fill_prefix(inner, req, span).map_err(|e| format!("{e:#}"))
        })
        .map_err(|msg| anyhow::anyhow!(msg))?;

    let sel: Range<usize> = entry.index.body_range(req.stages)?;
    let total = sel.len() as u64;
    if req.offset > total {
        bail!("offset {} beyond selected body ({total} bytes)", req.offset);
    }
    let serve_from = sel.start + req.offset as usize;
    let cached_upto = entry.prefix_len.min(sel.end).max(serve_from);
    let cache_part = serve_from..cached_upto;
    let tail = cached_upto..sel.end;

    // open the origin tail *before* the status frame so a dead origin
    // becomes a clean error frame, not a truncated body. The relay span
    // covers the whole phase — origin connect through the last tail byte.
    let mut relay_span = if tail.is_empty() {
        None
    } else {
        span.map(|ctx| obs::begin_child("edge.relay", ctx))
    };
    let mut origin_tail = if tail.is_empty() {
        None
    } else {
        let mut treq = req.clone().with_offset((tail.start - sel.start) as u64);
        treq.speed_mbps = inner.cfg.origin_speed_mbps;
        treq.keep_alive = false;
        // re-parent the origin leg under the relay span so the origin's
        // own request span nests inside this phase in the waterfall
        treq.trace = relay_span.as_ref().map(|sp| sp.ctx()).or(req.trace);
        let origin = pick_origin(inner, &req.model)?;
        let (tstream, tresp) = open_fetch(&origin, &treq).context("edge->origin tail")?;
        if tresp.container_len != entry.container_len {
            bail!(
                "{STALE_MARKER}: origin container {} != cached {}",
                tresp.container_len,
                entry.container_len
            );
        }
        if tresp.remaining != tail.len() as u64 {
            bail!(
                "origin tail advertises {} bytes, expected {}",
                tresp.remaining,
                tail.len()
            );
        }
        Some(tstream)
    };

    proto::write_ok(
        stream,
        &FetchResponse {
            total,
            remaining: total - req.offset,
            container_len: entry.container_len,
            stages: req.stages,
        },
    )?;

    // client-side shaping honours the client's requested link speed
    let shaped = req
        .speed_mbps
        .filter(|mbps| mbps.is_finite() && *mbps > 0.0);
    let mut out: Box<dyn Write + '_> = match shaped {
        Some(mbps) => Box::new(ThrottledWriter::new(&mut *stream, LinkSpec::mbps(mbps))),
        None => Box::new(&mut *stream),
    };

    if !cache_part.is_empty() {
        let mut cache_span = span.map(|ctx| obs::begin_child("edge.cache", ctx));
        out.write_all(&entry.bytes[cache_part.clone()])?;
        inner
            .stats
            .cache_bytes
            .fetch_add(cache_part.len() as u64, Ordering::SeqCst);
        inner.stats.edge_hits.fetch_add(1, Ordering::SeqCst);
        if let Some(sp) = cache_span.as_mut() {
            sp.attr("bytes", cache_part.len());
        }
    }
    if let Some(tstream) = origin_tail.as_mut() {
        tstream.set_read_timeout(Some(inner.cfg.io_timeout))?;
        let mut left = tail.len();
        let mut buf = [0u8; 16 * 1024];
        while left > 0 {
            let n = tstream.read(&mut buf[..left.min(buf.len())])?;
            if n == 0 {
                bail!("origin closed mid-tail with {left} bytes left");
            }
            out.write_all(&buf[..n])?;
            left -= n;
        }
        inner
            .stats
            .relay_bytes
            .fetch_add(tail.len() as u64, Ordering::SeqCst);
        inner.stats.edge_misses.fetch_add(1, Ordering::SeqCst);
        if let Some(mut sp) = relay_span.take() {
            sp.attr("bytes", tail.len());
            sp.end();
        }
    }
    out.flush()?;
    drop(out);
    inner
        .stats
        .bytes_sent
        .fetch_add((total - req.offset) as u64, Ordering::SeqCst);
    Ok(())
}

fn pick_origin(inner: &Inner, model: &str) -> Result<SocketAddr> {
    let i = inner
        .ring
        .place(model)
        .ok_or_else(|| anyhow::anyhow!("no origin configured"))?;
    Ok(inner.origins[i])
}

/// Fetch and validate stages `[0, k)` from the origin (single-flight
/// leader path). Two requests on one keep-alive connection: `[0, 1)` to
/// learn the manifest, then `[1, k)` for the rest of the prefix.
fn fill_prefix(
    inner: &Inner,
    req: &FetchRequest,
    span: Option<TraceCtx>,
) -> Result<Arc<PrefixEntry>> {
    // fills are single-flight: the span (and hence the trace) belongs to
    // the request that won the flight and actually performed the fill
    let mut fill_span = span.map(|ctx| obs::begin_child("edge.fill", ctx));
    let fill_ctx = fill_span.as_ref().map(|sp| sp.ctx());
    let origin = pick_origin(inner, &req.model)?;
    let mut first = FetchRequest::new(&req.model).with_stages(0, 1).with_keep_alive(true);
    first.schedule = req.schedule.clone();
    first.speed_mbps = inner.cfg.origin_speed_mbps;
    first.trace = fill_ctx;
    let (mut stream, resp) = open_fetch(&origin, &first).context("edge->origin fill")?;
    if resp.stages != Some((0, 1)) {
        bail!("origin rewrote fill range to {:?}", resp.stages);
    }
    stream.set_read_timeout(Some(inner.cfg.io_timeout))?;
    let container_len = resp.container_len;
    let mut bytes = read_exactly(&mut stream, resp.remaining as usize)?;

    // the stage-0 body carries the preamble: parse it for the manifest
    let mut probe = FrameParser::for_stage_prefix(1);
    probe.feed(&bytes).context("parsing fill head")?;
    let manifest = probe
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("fill head lacked a manifest"))?
        .clone();
    let total_stages = manifest.schedule.stages() as u32;
    let k = inner.cfg.prefix_stages.min(total_stages);

    if k > 1 {
        let mut rest = FetchRequest::new(&req.model).with_stages(1, k);
        rest.schedule = req.schedule.clone();
        rest.speed_mbps = inner.cfg.origin_speed_mbps;
        rest.trace = fill_ctx;
        let rresp = request_on(&mut stream, &rest).context("edge->origin fill tail")?;
        if rresp.stages != Some((1, k)) {
            bail!("origin rewrote fill range to {:?}", rresp.stages);
        }
        if rresp.container_len != container_len {
            bail!("origin container length changed mid-fill");
        }
        bytes.extend_from_slice(&read_exactly(&mut stream, rresp.remaining as usize)?);
    }

    // re-validate the assembled prefix end to end (frame CRCs included)
    // before publishing it to every future request on this edge
    let (valid_len, valid_stages) = validated_prefix(&bytes);
    if valid_stages != k as usize || valid_len != bytes.len() {
        bail!(
            "fill validation failed: {}/{} bytes, {}/{} stages usable",
            valid_len,
            bytes.len(),
            valid_stages,
            k
        );
    }
    let index = StageIndex::from_manifest(&manifest);
    if index.total_len() as u64 != container_len {
        bail!(
            "manifest says {} container bytes, origin advertised {container_len}",
            index.total_len()
        );
    }
    let prefix_len = bytes.len();
    if let Some(sp) = fill_span.as_mut() {
        sp.attr("bytes", prefix_len);
        sp.attr("stages", k);
    }
    inner.stats.origin_fills.fetch_add(1, Ordering::SeqCst);
    inner
        .stats
        .fill_bytes
        .fetch_add(prefix_len as u64, Ordering::SeqCst);
    crate::log_info!(
        "edge filled {} [0, {k}): {prefix_len} of {container_len} bytes",
        req.model
    );
    Ok(Arc::new(PrefixEntry {
        bytes,
        index,
        prefix_len,
        container_len,
    }))
}

fn read_exactly(stream: &mut TcpStream, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf).context("reading origin body")?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Schedule;
    use crate::testutil::fixture;
    use crate::util::sync::atomic::Ordering;

    fn edge_over(tag: &str) -> (Edge, crate::server::Server, Arc<crate::server::Repository>) {
        let (server, repo) = fixture::executable_server(tag).unwrap();
        let edge = Edge::start(
            "127.0.0.1:0",
            vec![server.addr()],
            EdgeConfig::default(),
        )
        .unwrap();
        (edge, server, repo)
    }

    #[test]
    fn cold_fetch_is_bit_identical_to_origin() {
        let (edge, _server, repo) = edge_over("edge-cold");
        let expect = repo.container("dense3", &Schedule::paper_default()).unwrap();
        let (mut s, resp) = open_fetch(&edge.addr(), &FetchRequest::new("dense3")).unwrap();
        assert_eq!(resp.total as usize, expect.len());
        assert_eq!(resp.container_len as usize, expect.len());
        let mut got = Vec::new();
        s.read_to_end(&mut got).unwrap();
        assert_eq!(&got[..], &expect[..], "edge body must match origin exactly");
        let st = edge.stats();
        assert_eq!(st.origin_fills.load(Ordering::SeqCst), 1);
        assert_eq!(st.edge_hits.load(Ordering::SeqCst), 1);
        assert_eq!(st.edge_misses.load(Ordering::SeqCst), 1, "tail was relayed");
    }

    #[test]
    fn warm_prefix_requests_never_touch_the_origin() {
        let (edge, server, _repo) = edge_over("edge-warm");
        // warm the cache
        let (mut s, resp) =
            open_fetch(&edge.addr(), &FetchRequest::new("dense3").with_stages(0, 2)).unwrap();
        let mut first = Vec::new();
        s.read_to_end(&mut first).unwrap();
        assert_eq!(first.len() as u64, resp.remaining);
        let origin_bytes = server.stats().bytes_sent.load(Ordering::SeqCst);
        let fills = edge.stats().origin_fills.load(Ordering::SeqCst);
        assert_eq!(fills, 1);
        // ten warm prefix fetches: origin byte counter must not move
        for _ in 0..10 {
            let (mut s, _) =
                open_fetch(&edge.addr(), &FetchRequest::new("dense3").with_stages(0, 2)).unwrap();
            let mut got = Vec::new();
            s.read_to_end(&mut got).unwrap();
            assert_eq!(got, first);
        }
        assert_eq!(
            server.stats().bytes_sent.load(Ordering::SeqCst),
            origin_bytes,
            "warm prefix hits must be served entirely from the edge"
        );
        assert_eq!(edge.stats().origin_fills.load(Ordering::SeqCst), fills);
        assert_eq!(edge.stats().edge_misses.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn concurrent_cold_clients_fill_once() {
        let (edge, _server, _repo) = edge_over("edge-flight");
        let addr = edge.addr();
        let barrier = Arc::new(crate::util::sync::Barrier::new(6));
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let (mut s, _) =
                        open_fetch(&addr, &FetchRequest::new("dense3").with_stages(0, 2)).unwrap();
                    let mut got = Vec::new();
                    s.read_to_end(&mut got).unwrap();
                    got
                })
            })
            .collect();
        let bodies: Vec<Vec<u8>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for b in &bodies[1..] {
            assert_eq!(b, &bodies[0]);
        }
        assert_eq!(
            edge.stats().origin_fills.load(Ordering::SeqCst),
            1,
            "cold stampede must single-flight the fill"
        );
    }

    #[test]
    fn offset_resume_through_the_edge() {
        let (edge, _server, repo) = edge_over("edge-resume");
        let expect = repo.container("dense3", &Schedule::paper_default()).unwrap();
        // resume points on both sides of the prefix/tail seam
        let seam = expect.body_range(Some((0, 2))).unwrap().end as u64;
        for off in [1, seam / 2, seam, seam + 1, expect.len() as u64 - 1] {
            let (mut s, resp) =
                open_fetch(&edge.addr(), &FetchRequest::new("dense3").with_offset(off)).unwrap();
            assert_eq!(resp.remaining, expect.len() as u64 - off, "offset {off}");
            let mut got = Vec::new();
            s.read_to_end(&mut got).unwrap();
            assert_eq!(&got[..], &expect[off as usize..], "offset {off}");
        }
    }

    #[test]
    fn unknown_model_propagates_an_error_frame() {
        let (edge, _server, _repo) = edge_over("edge-unknown");
        let err = open_fetch(&edge.addr(), &FetchRequest::new("missing")).unwrap_err();
        assert!(err.to_string().contains("ERR"), "{err}");
    }

    #[test]
    fn keep_alive_serves_ranges_back_to_back() {
        let (edge, _server, repo) = edge_over("edge-keepalive");
        let expect = repo.container("dense3", &Schedule::paper_default()).unwrap();
        let mut stream = TcpStream::connect(edge.addr()).unwrap();
        stream.set_nodelay(true).unwrap();
        for stages in [(0u32, 2u32), (2, 8), (0, 8)] {
            let req = FetchRequest::new("dense3")
                .with_stages(stages.0, stages.1)
                .with_keep_alive(true);
            let resp = request_on(&mut stream, &req).unwrap();
            let mut body = vec![0u8; resp.remaining as usize];
            stream.read_exact(&mut body).unwrap();
            let want = expect.slice(expect.body_range(Some(stages)).unwrap());
            assert_eq!(&body[..], want, "{stages:?}");
        }
    }

    #[test]
    fn probe_connect_and_close_is_not_an_error() {
        let (edge, _server, _repo) = edge_over("edge-probe");
        for _ in 0..3 {
            drop(TcpStream::connect(edge.addr()).unwrap());
        }
        // give the handler threads a moment to run down
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while edge.stats().active.load(Ordering::SeqCst) != 0 {
            assert!(std::time::Instant::now() < deadline, "handlers stuck");
            std::thread::yield_now();
        }
        assert_eq!(edge.stats().errors.load(Ordering::SeqCst), 0);
    }
}
